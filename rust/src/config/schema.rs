//! Typed configuration schema with validation.
//!
//! A deployment is described by a JSON document:
//!
//! ```json
//! {
//!   "code":      {"scheme": "hierarchical",
//!                 "n1": 4, "k1": 2, "n2": 4, "k2": 2},
//!   "straggler": {"model": "exponential", "mu1": 10.0, "mu2": 1.0,
//!                 "scale": 0.02},
//!   "runtime":   {"artifact_dir": "artifacts", "use_pjrt": true,
//!                 "decode_threads": 4},
//!   "batching":  {"max_batch": 8, "max_wait_ms": 5.0},
//!   "serving":   {"queue_cap": 64, "default_deadline_ms": 10000,
//!                 "drain_ms": 5000,
//!                 "models": [{"name": "a", "rows": 1024, "cols": 128}]},
//!   "chaos":     {"liveness": true, "heartbeat_ms": 25,
//!                 "suspect_ms": 1000, "dead_ms": 5000}
//! }
//! ```
//!
//! `code.scheme` selects the coding scheme the cluster runs
//! (`hierarchical | mds | product | replication | polynomial`, default
//! `hierarchical`). Grid schemes use `(n1,k1)×(n2,k2)` directly; flat
//! schemes use `n = n1·n2`, `k = k1·k2` so every scheme deploys the
//! same worker count and recovery threshold (§IV's comparison).
//!
//! # Heterogeneous groups (the scenario layer)
//!
//! Instead of the uniform `(n1,k1,n2,k2)` sugar, the `"code"` object
//! may carry a `groups` array describing each group (rack) separately —
//! worker count, recovery threshold, and an optional per-group
//! straggler profile overriding the global `"straggler"` section:
//!
//! ```json
//! {
//!   "code": {"scheme": "hierarchical", "k2": 2,
//!            "groups": [
//!              {"n1": 4, "k1": 2},
//!              {"n1": 6, "k1": 3, "mu1": 2.0, "scale": 2.0},
//!              {"n1": 5, "k1": 2, "dead_workers": [4]}
//!            ]},
//!   "straggler": {"mu1": 10.0, "mu2": 1.0}
//! }
//! ```
//!
//! A group's `scale` is a *relative slowdown multiplier* on its worker
//! and link delays (2.0 = twice as slow), applied by the live cluster
//! **and** by every simulator/bound/allocator path — the global
//! `straggler.scale` stays the wall-clock rendering knob.
//!
//! # Partial-work mode (`subtasks_per_worker`)
//!
//! `code.subtasks_per_worker = r` (default 1) splits every worker's
//! shard into `r` sequentially-computed coded sub-tasks (per-group
//! `(n1·r, k1·r)` MDS layering on the hierarchical inner code): workers
//! stream one partial result per completed sub-task and a group decodes
//! from **any** `k1·r` sub-results — harvesting stragglers' partial
//! work instead of discarding it (Ferdinand–Draper, arXiv:1806.10250).
//! Per-group override: a `subtasks` field on a `groups` entry. `r = 1`
//! is bit-identical to the all-or-nothing model on every layer;
//! `r > 1` requires the hierarchical scheme and (for now) the native
//! backend.
//!
//! Both forms expand into the same [`Topology`] value, which then
//! drives the coding layer (per-group generators), the coordinator
//! (per-group spawn + thresholds + delays) and the simulator — the
//! uniform form is pure sugar for a `groups` array of identical
//! entries. Per-group `mu1`/`mu2` overrides are the paper's
//! exponential rates; `dead_workers` bakes failure domains into the
//! scenario. The `groups` form requires the hierarchical scheme — the
//! baselines have no per-group decode to size and would silently drop
//! the per-group profiles at launch.

use crate::coding::hierarchical::HierarchicalParams;
use crate::coding::{CodedScheme, SchemeKind};
use crate::config::json::Json;
use crate::scenario::{GroupSpec, Topology};
use crate::sim::straggler::StragglerModel;
use crate::{Error, Result};
use std::sync::Arc;

/// The coding-scheme selection plus the expanded scenario topology.
/// `n1/k1/n2/k2` hold the uniform grid view (for heterogeneous
/// topologies: the first group's values, retained for the flat-scheme
/// construction paths and display); `topology` is the authoritative
/// per-group expansion every layer consumes.
#[derive(Clone, Debug, PartialEq)]
pub struct CodeConfig {
    /// Which scheme the cluster runs.
    pub scheme: SchemeKind,
    /// Workers per group (uniform view).
    pub n1: usize,
    /// Inner code dimension (uniform view).
    pub k1: usize,
    /// Number of groups.
    pub n2: usize,
    /// Outer code dimension.
    pub k2: usize,
    /// The expanded scenario: per-group `(n1_g, k1_g)` + straggler
    /// profiles. Uniform configs expand to identical groups.
    pub topology: Topology,
}

/// Parse an optional per-group exponential-rate override (`mu1`/`mu2`),
/// falling back to the given default model.
fn group_rate(
    v: &Json,
    key: &str,
    ctx: &str,
    default: StragglerModel,
) -> Result<StragglerModel> {
    match v.get(key) {
        Some(m) => {
            let mu = m.as_f64().ok_or_else(|| {
                Error::Config(format!("{ctx}: field '{key}' must be a number"))
            })?;
            if !mu.is_finite() || mu <= 0.0 {
                return Err(Error::Config(format!(
                    "{ctx}: {key} must be a positive finite rate"
                )));
            }
            Ok(StragglerModel::exp(mu))
        }
        None => Ok(default),
    }
}

/// Parse an optional per-worker sub-task count (`1..=MAX_SUBTASKS`),
/// falling back to `default`.
fn subtasks_field(v: &Json, key: &str, ctx: &str, default: usize) -> Result<usize> {
    match v.get(key) {
        None => Ok(default),
        Some(s) => {
            let r = s.as_usize().ok_or_else(|| {
                Error::Config(format!("{ctx}: field '{key}' must be a positive integer"))
            })?;
            if r == 0 || r > crate::scenario::MAX_SUBTASKS {
                return Err(Error::Config(format!(
                    "{ctx}: {key} must be in 1..={}, got {r}",
                    crate::scenario::MAX_SUBTASKS
                )));
            }
            Ok(r)
        }
    }
}

/// Parse one entry of the `groups` array.
fn group_from_json(
    v: &Json,
    index: usize,
    defaults: &StragglerConfig,
    default_subtasks: usize,
) -> Result<GroupSpec> {
    let ctx = format!("code.groups[{index}]");
    let n1 = v.req_usize("n1", &ctx)?;
    let k1 = v.req_usize("k1", &ctx)?;
    let subtasks = subtasks_field(v, "subtasks", &ctx, default_subtasks)?;
    let worker = group_rate(v, "mu1", &ctx, defaults.worker)?;
    let link = group_rate(v, "mu2", &ctx, defaults.link)?;
    let scale = match v.get("scale") {
        Some(s) => {
            let m = s.as_f64().ok_or_else(|| {
                Error::Config(format!("{ctx}: field 'scale' must be a number"))
            })?;
            if !m.is_finite() || m <= 0.0 {
                return Err(Error::Config(format!(
                    "{ctx}: scale must be a positive slowdown multiplier, got {m}"
                )));
            }
            Some(m)
        }
        None => None,
    };
    let dead_workers = match v.get("dead_workers") {
        Some(ds) => ds
            .as_array()
            .ok_or_else(|| {
                Error::Config(format!("{ctx}: field 'dead_workers' must be an array"))
            })?
            .iter()
            .map(|d| {
                d.as_usize().ok_or_else(|| {
                    Error::Config(format!(
                        "{ctx}: dead_workers entries must be non-negative integers"
                    ))
                })
            })
            .collect::<Result<Vec<usize>>>()?,
        None => Vec::new(),
    };
    Ok(GroupSpec {
        n1,
        k1,
        worker,
        link,
        scale,
        dead_workers,
        subtasks,
    })
}

impl CodeConfig {
    /// Parse from the `"code"` object, using the already-parsed global
    /// straggler section as the default per-group profile.
    pub fn from_json(v: &Json, straggler: &StragglerConfig) -> Result<Self> {
        let scheme = match v.get("scheme").and_then(|s| s.as_str()) {
            Some(name) => SchemeKind::parse(name)?,
            None => SchemeKind::Hierarchical,
        };
        // Partial-work mode: the uniform sub-task count every group
        // inherits (per-group 'subtasks' entries override it). `1` is
        // the paper's all-or-nothing task model.
        let subtasks = subtasks_field(v, "subtasks_per_worker", "code", 1)?;
        let c = match v.get("groups") {
            Some(gs) => {
                // The groups form is the scenario layer of the scheme
                // whose decode is per-group. The baselines would accept
                // the per-group profiles at parse time and then drop
                // them at launch (their topologies carry no profiles) —
                // exactly the sim/live drift this layer exists to kill,
                // so reject it outright.
                if scheme != SchemeKind::Hierarchical {
                    return Err(Error::Config(format!(
                        "code: 'groups' requires the hierarchical scheme \
                         (got '{scheme}'); use the uniform n1/k1/n2/k2 form"
                    )));
                }
                let arr = gs.as_array().ok_or_else(|| {
                    Error::Config("code: field 'groups' must be an array".into())
                })?;
                if arr.is_empty() {
                    return Err(Error::Config("code: 'groups' must be non-empty".into()));
                }
                for dup in ["n1", "k1"] {
                    if v.get(dup).is_some() {
                        return Err(Error::Config(format!(
                            "code: '{dup}' conflicts with 'groups' (uniform sugar and \
                             per-group specs are mutually exclusive)"
                        )));
                    }
                }
                let k2 = v.req_usize("k2", "code")?;
                if v.get("n2").is_some() {
                    // A present n2 must be well-formed and agree with
                    // the group count (same strictness as 'seed').
                    let n2 = v.req_usize("n2", "code")?;
                    if n2 != arr.len() {
                        return Err(Error::Config(format!(
                            "code: n2 = {n2} contradicts the {} entries of 'groups'",
                            arr.len()
                        )));
                    }
                }
                let groups = arr
                    .iter()
                    .enumerate()
                    .map(|(i, g)| group_from_json(g, i, straggler, subtasks))
                    .collect::<Result<Vec<GroupSpec>>>()?;
                let topology = Topology { groups, k2 };
                Self {
                    scheme,
                    n1: topology.groups[0].n1,
                    k1: topology.groups[0].k1,
                    n2: topology.n2(),
                    k2,
                    topology,
                }
            }
            None => {
                let (n1, k1) = (v.req_usize("n1", "code")?, v.req_usize("k1", "code")?);
                let (n2, k2) = (v.req_usize("n2", "code")?, v.req_usize("k2", "code")?);
                let mut c = Self::uniform_with_profile(scheme, n1, k1, n2, k2, straggler);
                for g in &mut c.topology.groups {
                    g.subtasks = subtasks;
                }
                c
            }
        };
        c.validate()?;
        Ok(c)
    }

    /// The uniform `(n1,k1)×(n2,k2)` sugar, expanded into identical
    /// per-group specs carrying the global straggler profile.
    pub fn uniform_with_profile(
        scheme: SchemeKind,
        n1: usize,
        k1: usize,
        n2: usize,
        k2: usize,
        straggler: &StragglerConfig,
    ) -> Self {
        let topology = Topology {
            groups: (0..n2)
                .map(|_| GroupSpec {
                    n1,
                    k1,
                    worker: straggler.worker,
                    link: straggler.link,
                    scale: None,
                    dead_workers: Vec::new(),
                    subtasks: 1,
                })
                .collect(),
            k2,
        };
        Self {
            scheme,
            n1,
            k1,
            n2,
            k2,
            topology,
        }
    }

    /// Validate the parameters for the selected scheme.
    pub fn validate(&self) -> Result<()> {
        self.topology.validate()?;
        if self.scheme != SchemeKind::Hierarchical && !self.topology.is_uniform_code() {
            return Err(Error::InvalidParams(format!(
                "{}: heterogeneous 'groups' require the hierarchical scheme",
                self.scheme
            )));
        }
        if self.scheme != SchemeKind::Hierarchical
            && self.topology.groups.iter().any(|g| g.subtasks > 1)
        {
            return Err(Error::InvalidParams(format!(
                "{}: subtasks_per_worker > 1 requires the hierarchical scheme \
                 (partial-work mode is per-group MDS layering on the inner code)",
                self.scheme
            )));
        }
        let (n, k) = (self.n1 * self.n2, self.k1 * self.k2);
        match self.scheme {
            SchemeKind::Hierarchical => self.topology.hierarchical_params().validate(),
            SchemeKind::Product => self.to_params().validate(),
            SchemeKind::Mds | SchemeKind::Polynomial => {
                if k == 0 || k > n {
                    return Err(Error::InvalidParams(format!(
                        "{}: need 1 <= k1·k2 <= n1·n2, got ({n}, {k})",
                        self.scheme
                    )));
                }
                Ok(())
            }
            SchemeKind::Replication => {
                if k == 0 || k > n || n % k != 0 {
                    return Err(Error::InvalidParams(format!(
                        "replication: need k1·k2 ({k}) dividing n1·n2 ({n})"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Build the configured scheme (serial decoders; the cluster path
    /// goes through [`ClusterConfig::build_scheme`] to attach a pool).
    pub fn build(&self) -> Result<Arc<dyn CodedScheme>> {
        crate::coding::build_scheme_topology(self.scheme, &self.topology, 1)
    }

    /// Convert to [`HierarchicalParams`] (homogeneous) — meaningful for
    /// the grid schemes.
    pub fn to_params(&self) -> HierarchicalParams {
        HierarchicalParams::homogeneous(self.n1, self.k1, self.n2, self.k2)
    }
}

/// Straggler-injection configuration for the in-process cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerConfig {
    /// Worker compute-delay model.
    pub worker: StragglerModel,
    /// Group→master link-delay model.
    pub link: StragglerModel,
    /// Wall-clock seconds per model time unit (the paper's µ are in
    /// abstract time units; `scale` maps them onto real sleeps).
    pub scale: f64,
    /// Whether delays are injected at all (off for pure-throughput runs).
    pub enabled: bool,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        Self {
            worker: StragglerModel::exp(10.0),
            link: StragglerModel::exp(1.0),
            scale: 0.01,
            enabled: true,
        }
    }
}

impl StragglerConfig {
    /// Parse from the `"straggler"` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        let model = v
            .get("model")
            .and_then(|m| m.as_str())
            .unwrap_or("exponential")
            .to_string();
        let mu1 = v.req_f64("mu1", "straggler")?;
        let mu2 = v.req_f64("mu2", "straggler")?;
        if mu1 <= 0.0 || mu2 <= 0.0 {
            return Err(Error::Config("straggler rates must be positive".into()));
        }
        let (worker, link) = match model.as_str() {
            "exponential" => (StragglerModel::exp(mu1), StragglerModel::exp(mu2)),
            "shifted" => {
                let shift = v.req_f64("shift", "straggler")?;
                (
                    StragglerModel::ShiftedExponential { shift, mu: mu1 },
                    StragglerModel::exp(mu2),
                )
            }
            "deterministic" => (
                StragglerModel::Deterministic { value: 1.0 / mu1 },
                StragglerModel::Deterministic { value: 1.0 / mu2 },
            ),
            other => {
                return Err(Error::Config(format!(
                    "unknown straggler model '{other}' (expected exponential|shifted|deterministic)"
                )))
            }
        };
        Ok(Self {
            worker,
            link,
            scale: v.get("scale").and_then(|s| s.as_f64()).unwrap_or(0.01),
            enabled: v.get("enabled").and_then(|e| e.as_bool()).unwrap_or(true),
        })
    }
}

/// PJRT runtime configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RuntimeConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifact_dir: String,
    /// Execute worker products through PJRT (false = pure-Rust fallback,
    /// used by tests that must run without artifacts).
    pub use_pjrt: bool,
    /// Width of the decode pool every decoder session fans across:
    /// group eliminations and the multi-RHS solve's column panels.
    /// `0` = all available cores; values above
    /// [`crate::parallel::MAX_THREADS`] are rejected at parse time.
    pub decode_threads: usize,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self {
            artifact_dir: "artifacts".to_string(),
            use_pjrt: true,
            decode_threads: 4,
        }
    }
}

impl RuntimeConfig {
    /// Parse from the `"runtime"` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let decode_threads = v
            .get("decode_threads")
            .and_then(|t| t.as_usize())
            .unwrap_or(d.decode_threads);
        if decode_threads > crate::parallel::MAX_THREADS {
            return Err(Error::Config(format!(
                "runtime.decode_threads = {decode_threads} exceeds the {} ceiling \
                 (use 0 for all available cores)",
                crate::parallel::MAX_THREADS
            )));
        }
        Ok(Self {
            artifact_dir: v
                .get("artifact_dir")
                .and_then(|a| a.as_str())
                .unwrap_or(&d.artifact_dir)
                .to_string(),
            use_pjrt: v.get("use_pjrt").and_then(|u| u.as_bool()).unwrap_or(d.use_pjrt),
            decode_threads,
        })
    }
}

/// Request batching policy.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchConfig {
    /// Maximum requests folded into one coded job.
    pub max_batch: usize,
    /// Maximum time the batcher holds a request open (milliseconds).
    pub max_wait_ms: f64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait_ms: 5.0,
        }
    }
}

impl BatchConfig {
    /// Parse from the `"batching"` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let c = Self {
            max_batch: v.get("max_batch").and_then(|b| b.as_usize()).unwrap_or(d.max_batch),
            max_wait_ms: v
                .get("max_wait_ms")
                .and_then(|w| w.as_f64())
                .unwrap_or(d.max_wait_ms),
        };
        if c.max_batch == 0 {
            return Err(Error::Config("max_batch must be >= 1".into()));
        }
        Ok(c)
    }
}

/// One entry of the serving model table: a named computation registered
/// at launch. The matrix is synthetic — `rows × cols`, seeded — which
/// is exactly what the `serve`/`loadgen` workloads need: a reproducible
/// multi-tenant setup in config form. Real callers register their own
/// matrices through `ClusterCore::register_model`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// Model name (submission routing key).
    pub name: String,
    /// Output dimension `m` (must divide by the scheme's row divisor).
    pub rows: usize,
    /// Input dimension `d`.
    pub cols: usize,
    /// Seed for the synthetic matrix entries.
    pub seed: u64,
}

/// Admission-control and drain policy for the multi-tenant job service.
#[derive(Clone, Debug, PartialEq)]
pub struct ServingConfig {
    /// Per-model admission cap: submissions beyond this many queued
    /// (accepted, undispatched) requests bounce with `Error::Busy`.
    pub queue_cap: usize,
    /// Default admission deadline (ms): a request still undispatched
    /// past this is shed with `Error::DeadlineExceeded`. Per-request
    /// override via `SubmitOptions::deadline`.
    pub default_deadline_ms: f64,
    /// Graceful-shutdown drain grace (ms): how long the master waits
    /// for in-flight jobs before failing their routes.
    pub drain_ms: f64,
    /// Models registered at launch (may be empty).
    pub models: Vec<ModelSpec>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            queue_cap: 64,
            default_deadline_ms: 10_000.0,
            drain_ms: 5_000.0,
            models: Vec::new(),
        }
    }
}

impl ServingConfig {
    /// Parse from the `"serving"` object. Malformed or degenerate
    /// values are rejected with actionable errors — never silently
    /// replaced by defaults.
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let queue_cap = match v.get("queue_cap") {
            Some(q) => q.as_usize().ok_or_else(|| {
                Error::Config(
                    "serving.queue_cap must be a non-negative integer".into(),
                )
            })?,
            None => d.queue_cap,
        };
        if queue_cap == 0 {
            return Err(Error::Config(
                "serving.queue_cap = 0 would reject every submission; \
                 use a positive per-model cap"
                    .into(),
            ));
        }
        let default_deadline_ms = match v.get("default_deadline_ms") {
            Some(x) => x.as_f64().ok_or_else(|| {
                Error::Config(
                    "serving.default_deadline_ms must be a number of milliseconds"
                        .into(),
                )
            })?,
            None => d.default_deadline_ms,
        };
        if !default_deadline_ms.is_finite() || default_deadline_ms <= 0.0 {
            return Err(Error::Config(format!(
                "serving.default_deadline_ms = {default_deadline_ms} would shed \
                 every request at its first flush; use a positive deadline"
            )));
        }
        let drain_ms = match v.get("drain_ms") {
            Some(x) => x.as_f64().ok_or_else(|| {
                Error::Config(
                    "serving.drain_ms must be a number of milliseconds".into(),
                )
            })?,
            None => d.drain_ms,
        };
        if !drain_ms.is_finite() || drain_ms <= 0.0 {
            return Err(Error::Config(format!(
                "serving.drain_ms = {drain_ms} would abandon in-flight jobs at \
                 shutdown; use a positive grace period"
            )));
        }
        let models = match v.get("models") {
            None => Vec::new(),
            Some(ms) => {
                let arr = ms.as_array().ok_or_else(|| {
                    Error::Config("serving.models must be an array".into())
                })?;
                let mut models = Vec::with_capacity(arr.len());
                let mut seen = std::collections::HashSet::new();
                for (i, entry) in arr.iter().enumerate() {
                    let ctx = format!("serving.models[{i}]");
                    let name = entry.req_str("name", &ctx)?;
                    if name.is_empty() {
                        return Err(Error::Config(format!(
                            "{ctx}: model name must be non-empty"
                        )));
                    }
                    if !seen.insert(name.clone()) {
                        return Err(Error::Config(format!(
                            "{ctx}: duplicate model name '{name}' \
                             (model names must be unique)"
                        )));
                    }
                    let rows = entry.req_usize("rows", &ctx)?;
                    let cols = entry.req_usize("cols", &ctx)?;
                    if rows == 0 || cols == 0 {
                        return Err(Error::Config(format!(
                            "{ctx}: model '{name}' needs positive rows and cols, \
                             got {rows}x{cols}"
                        )));
                    }
                    let seed = match entry.get("seed") {
                        Some(s) => s.as_usize().ok_or_else(|| {
                            Error::Config(format!(
                                "{ctx}: field 'seed' must be a non-negative integer"
                            ))
                        })? as u64,
                        None => 1 + i as u64,
                    };
                    models.push(ModelSpec {
                        name,
                        rows,
                        cols,
                        seed,
                    });
                }
                models
            }
        };
        Ok(Self {
            queue_cap,
            default_deadline_ms,
            drain_ms,
            models,
        })
    }
}

/// Liveness tracking and chaos-run policy.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosConfig {
    /// Master switch: heartbeats + the master's failure detector.
    pub liveness: bool,
    /// Heartbeat cadence (ms) for workers and submasters.
    pub heartbeat_ms: f64,
    /// Beacon silence (ms) after which a worker/group is Suspected.
    pub suspect_ms: f64,
    /// Beacon silence (ms) after which a worker/group is Dead.
    pub dead_ms: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            liveness: true,
            heartbeat_ms: 25.0,
            suspect_ms: 1_000.0,
            dead_ms: 5_000.0,
        }
    }
}

impl ChaosConfig {
    /// Parse from the `"chaos"` object. Malformed or degenerate values
    /// are rejected — never silently replaced by defaults.
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let liveness = match v.get("liveness") {
            Some(Json::Bool(b)) => *b,
            Some(_) => {
                return Err(Error::Config(
                    "chaos.liveness must be a boolean".into(),
                ))
            }
            None => d.liveness,
        };
        let ms_field = |key: &str, default: f64| -> Result<f64> {
            match v.get(key) {
                Some(x) => {
                    let ms = x.as_f64().ok_or_else(|| {
                        Error::Config(format!(
                            "chaos.{key} must be a number of milliseconds"
                        ))
                    })?;
                    if !ms.is_finite() || ms <= 0.0 {
                        return Err(Error::Config(format!(
                            "chaos.{key} = {ms} is not a positive finite \
                             duration"
                        )));
                    }
                    Ok(ms)
                }
                None => Ok(default),
            }
        };
        let heartbeat_ms = ms_field("heartbeat_ms", d.heartbeat_ms)?;
        let suspect_ms = ms_field("suspect_ms", d.suspect_ms)?;
        let dead_ms = ms_field("dead_ms", d.dead_ms)?;
        if !(heartbeat_ms < suspect_ms && suspect_ms <= dead_ms) {
            return Err(Error::Config(format!(
                "chaos thresholds must satisfy heartbeat_ms < suspect_ms <= \
                 dead_ms, got {heartbeat_ms} / {suspect_ms} / {dead_ms} \
                 (a cadence at or above the suspect window false-positives \
                 every sweep)"
            )));
        }
        Ok(Self {
            liveness,
            heartbeat_ms,
            suspect_ms,
            dead_ms,
        })
    }
}

/// Which transport carries master ↔ submaster traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportMode {
    /// In-process `mpsc` channels (the default fast path).
    Memory,
    /// Socket transport: the master binds `transport.listen` and
    /// `hiercode node` processes dial in.
    Socket,
}

/// Transport selection and socket-mode tuning.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    /// Memory (in-process) or Socket (multi-process).
    pub mode: TransportMode,
    /// Hub address in socket mode: `uds:<path>` or `tcp:host:port`.
    pub listen: String,
    /// How long launch helpers wait for every node to connect (ms).
    pub connect_wait_ms: f64,
    /// Node reconnect backoff base delay (ms).
    pub dial_backoff_ms: f64,
    /// Node reconnect backoff clamp (ms).
    pub dial_backoff_max_ms: f64,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            mode: TransportMode::Memory,
            listen: String::new(),
            connect_wait_ms: 10_000.0,
            dial_backoff_ms: 25.0,
            dial_backoff_max_ms: 1_000.0,
        }
    }
}

impl TransportConfig {
    /// Parse from the `"transport"` object. Malformed values are
    /// rejected — never silently replaced by defaults.
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let mode = match v.get("mode") {
            Some(Json::String(s)) => match s.as_str() {
                "memory" => TransportMode::Memory,
                "socket" => TransportMode::Socket,
                other => {
                    return Err(Error::Config(format!(
                        "transport.mode must be \"memory\" or \"socket\", \
                         got \"{other}\""
                    )))
                }
            },
            Some(_) => {
                return Err(Error::Config(
                    "transport.mode must be a string".into(),
                ))
            }
            None => d.mode,
        };
        let listen = match v.get("listen") {
            Some(Json::String(s)) => s.clone(),
            Some(_) => {
                return Err(Error::Config(
                    "transport.listen must be a string address".into(),
                ))
            }
            None => d.listen,
        };
        let ms_field = |key: &str, default: f64| -> Result<f64> {
            match v.get(key) {
                Some(x) => {
                    let ms = x.as_f64().ok_or_else(|| {
                        Error::Config(format!(
                            "transport.{key} must be a number of milliseconds"
                        ))
                    })?;
                    if !ms.is_finite() || ms <= 0.0 {
                        return Err(Error::Config(format!(
                            "transport.{key} = {ms} is not a positive finite \
                             duration"
                        )));
                    }
                    Ok(ms)
                }
                None => Ok(default),
            }
        };
        let connect_wait_ms = ms_field("connect_wait_ms", d.connect_wait_ms)?;
        let dial_backoff_ms = ms_field("dial_backoff_ms", d.dial_backoff_ms)?;
        let dial_backoff_max_ms = ms_field("dial_backoff_max_ms", d.dial_backoff_max_ms)?;
        if dial_backoff_max_ms < dial_backoff_ms {
            return Err(Error::Config(format!(
                "transport.dial_backoff_max_ms = {dial_backoff_max_ms} must be \
                 >= dial_backoff_ms = {dial_backoff_ms}"
            )));
        }
        if mode == TransportMode::Socket {
            // Fail at parse time, not at bind time: a socket-mode
            // config without a valid address is always a mistake.
            crate::transport::TransportAddr::parse(&listen).map_err(|e| {
                Error::Config(format!("transport.listen: {e}"))
            })?;
        }
        Ok(Self {
            mode,
            listen,
            connect_wait_ms,
            dial_backoff_ms,
            dial_backoff_max_ms,
        })
    }
}

/// Full cluster configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Code parameters.
    pub code: CodeConfig,
    /// Straggler injection.
    pub straggler: StragglerConfig,
    /// Runtime / artifacts.
    pub runtime: RuntimeConfig,
    /// Batching policy.
    pub batching: BatchConfig,
    /// Serving-layer admission control + model table.
    pub serving: ServingConfig,
    /// Liveness tracking (heartbeats + failure detector).
    pub chaos: ChaosConfig,
    /// Transport selection (in-process channels or sockets).
    pub transport: TransportConfig,
    /// RNG seed for straggler injection.
    pub seed: u64,
}

impl ClusterConfig {
    /// Build the configured scheme with `runtime.decode_threads` wired
    /// into its decode pool — the one construction path the live
    /// cluster uses, so the config field actually drives the decoders
    /// and the expanded [`Topology`] drives the spawn layout.
    pub fn build_scheme(&self) -> Result<Arc<dyn CodedScheme>> {
        crate::coding::build_scheme_topology(
            self.code.scheme,
            &self.code.topology,
            self.runtime.decode_threads,
        )
    }

    /// Parse a full config document.
    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        // Straggler first: its models are the per-group defaults the
        // code section's `groups` entries inherit.
        let straggler = match v.get("straggler") {
            Some(s) => StragglerConfig::from_json(s)?,
            None => StragglerConfig::default(),
        };
        let code = CodeConfig::from_json(v.req("code", "config")?, &straggler)?;
        let runtime = match v.get("runtime") {
            Some(r) => RuntimeConfig::from_json(r)?,
            None => RuntimeConfig::default(),
        };
        let batching = match v.get("batching") {
            Some(b) => BatchConfig::from_json(b)?,
            None => BatchConfig::default(),
        };
        let serving = match v.get("serving") {
            Some(s) => ServingConfig::from_json(s)?,
            None => ServingConfig::default(),
        };
        let chaos = match v.get("chaos") {
            Some(c) => ChaosConfig::from_json(c)?,
            None => ChaosConfig::default(),
        };
        let transport = match v.get("transport") {
            Some(t) => TransportConfig::from_json(t)?,
            None => TransportConfig::default(),
        };
        let seed = match v.get("seed") {
            // A present-but-malformed seed is a config mistake, not a
            // request for the default: reject it instead of silently
            // running an unexpected RNG stream.
            Some(s) => s.as_usize().ok_or_else(|| {
                Error::Config(
                    "config: field 'seed' must be a non-negative integer".into(),
                )
            })? as u64,
            None => 42,
        };
        Ok(Self {
            code,
            straggler,
            runtime,
            batching,
            serving,
            chaos,
            transport,
            seed,
        })
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {path}: {e}")))?;
        Self::from_json_text(&text)
    }

    /// A small test/demo config (no PJRT required) for any scheme.
    pub fn demo_scheme(scheme: SchemeKind, n1: usize, k1: usize, n2: usize, k2: usize) -> Self {
        let mut c = Self::demo(n1, k1, n2, k2);
        c.code.scheme = scheme;
        c
    }

    /// A small test/demo config (no PJRT required), hierarchical.
    pub fn demo(n1: usize, k1: usize, n2: usize, k2: usize) -> Self {
        let straggler = StragglerConfig {
            scale: 0.001,
            ..StragglerConfig::default()
        };
        Self {
            code: CodeConfig::uniform_with_profile(
                SchemeKind::Hierarchical,
                n1,
                k1,
                n2,
                k2,
                &straggler,
            ),
            straggler,
            runtime: RuntimeConfig {
                use_pjrt: false,
                decode_threads: 2,
                ..RuntimeConfig::default()
            },
            batching: BatchConfig::default(),
            serving: ServingConfig::default(),
            chaos: ChaosConfig::default(),
            transport: TransportConfig::default(),
            seed: 42,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"{
        "code": {"n1": 4, "k1": 2, "n2": 3, "k2": 2},
        "straggler": {"model": "exponential", "mu1": 10.0, "mu2": 1.0,
                      "scale": 0.02, "enabled": true},
        "runtime": {"artifact_dir": "artifacts", "use_pjrt": false,
                    "decode_threads": 3},
        "batching": {"max_batch": 4, "max_wait_ms": 2.5},
        "seed": 7
    }"#;

    #[test]
    fn chaos_section_parses_and_validates() {
        const CODE: &str = r#""code": {"n1": 2, "k1": 1, "n2": 2, "k2": 1}"#;
        let c = ClusterConfig::from_json_text(&format!(
            r#"{{{CODE}, "chaos": {{"liveness": true, "heartbeat_ms": 10,
                "suspect_ms": 100, "dead_ms": 400}}}}"#
        ))
        .unwrap();
        assert!(c.chaos.liveness);
        assert_eq!(c.chaos.heartbeat_ms, 10.0);
        assert_eq!(c.chaos.dead_ms, 400.0);
        // Absent section → defaults (liveness on).
        let c = ClusterConfig::from_json_text(&format!("{{{CODE}}}")).unwrap();
        assert_eq!(c.chaos, ChaosConfig::default());
        // Present-but-malformed values are rejected, never defaulted.
        for bad in [
            r#"{"liveness": "yes"}"#,
            r#"{"heartbeat_ms": "fast"}"#,
            r#"{"heartbeat_ms": 0}"#,
            r#"{"suspect_ms": -5}"#,
            // cadence at/above suspect window: detector would
            // false-positive between beats
            r#"{"heartbeat_ms": 200, "suspect_ms": 100}"#,
            r#"{"suspect_ms": 2000, "dead_ms": 100}"#,
        ] {
            let doc = format!(r#"{{{CODE}, "chaos": {bad}}}"#);
            assert!(
                ClusterConfig::from_json_text(&doc).is_err(),
                "accepted malformed chaos section {bad}"
            );
        }
        // liveness can be turned off while keeping valid thresholds.
        let c = ClusterConfig::from_json_text(&format!(
            r#"{{{CODE}, "chaos": {{"liveness": false}}}}"#
        ))
        .unwrap();
        assert!(!c.chaos.liveness);
    }

    #[test]
    fn transport_section_parses_and_validates() {
        const CODE: &str = r#""code": {"n1": 2, "k1": 1, "n2": 2, "k2": 1}"#;
        let c = ClusterConfig::from_json_text(&format!(
            r#"{{{CODE}, "transport": {{"mode": "socket",
                "listen": "uds:/tmp/h.sock", "connect_wait_ms": 500,
                "dial_backoff_ms": 10, "dial_backoff_max_ms": 100}}}}"#
        ))
        .unwrap();
        assert_eq!(c.transport.mode, TransportMode::Socket);
        assert_eq!(c.transport.listen, "uds:/tmp/h.sock");
        assert_eq!(c.transport.connect_wait_ms, 500.0);
        assert_eq!(c.transport.dial_backoff_ms, 10.0);
        assert_eq!(c.transport.dial_backoff_max_ms, 100.0);
        // Absent section → in-memory defaults.
        let c = ClusterConfig::from_json_text(&format!("{{{CODE}}}")).unwrap();
        assert_eq!(c.transport, TransportConfig::default());
        assert_eq!(c.transport.mode, TransportMode::Memory);
        // Present-but-malformed values are rejected, never defaulted.
        for bad in [
            r#"{"mode": "carrier-pigeon"}"#,
            r#"{"mode": 3}"#,
            r#"{"listen": 9}"#,
            r#"{"connect_wait_ms": "soon"}"#,
            r#"{"dial_backoff_ms": 0}"#,
            r#"{"dial_backoff_ms": 100, "dial_backoff_max_ms": 10}"#,
            // socket mode demands a parseable address
            r#"{"mode": "socket"}"#,
            r#"{"mode": "socket", "listen": "carrier:/x"}"#,
        ] {
            let doc = format!(r#"{{{CODE}, "transport": {bad}}}"#);
            assert!(
                ClusterConfig::from_json_text(&doc).is_err(),
                "accepted malformed transport section {bad}"
            );
        }
        // Memory mode tolerates an empty listen address.
        let c = ClusterConfig::from_json_text(&format!(
            r#"{{{CODE}, "transport": {{"mode": "memory"}}}}"#
        ))
        .unwrap();
        assert_eq!(c.transport.mode, TransportMode::Memory);
    }

    #[test]
    fn parses_full_config() {
        let c = ClusterConfig::from_json_text(FULL).unwrap();
        assert_eq!(c.code.scheme, SchemeKind::Hierarchical);
        assert_eq!(
            (c.code.n1, c.code.k1, c.code.n2, c.code.k2),
            (4, 2, 3, 2)
        );
        // The uniform sugar expands to identical per-group specs
        // carrying the global straggler profile.
        assert_eq!(c.code.topology.n2(), 3);
        assert!(c.code.topology.is_uniform_code());
        for g in &c.code.topology.groups {
            assert_eq!((g.n1, g.k1), (4, 2));
            assert_eq!(g.worker, c.straggler.worker);
            assert_eq!(g.link, c.straggler.link);
            assert!(g.dead_workers.is_empty());
        }
        assert_eq!(c.runtime.decode_threads, 3);
        assert!(!c.runtime.use_pjrt);
        assert_eq!(c.batching.max_batch, 4);
        assert_eq!(c.seed, 7);
        assert!(c.straggler.enabled);
        assert_eq!(c.straggler.scale, 0.02);
    }

    #[test]
    fn groups_array_parses_heterogeneous_topology() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "hierarchical", "k2": 2,
                         "groups": [
                           {"n1": 4, "k1": 2},
                           {"n1": 6, "k1": 3, "mu1": 2.5, "scale": 2.0},
                           {"n1": 5, "k1": 2, "mu2": 4.0, "dead_workers": [4]}
                         ]},
                "straggler": {"mu1": 10.0, "mu2": 1.0}}"#,
        )
        .unwrap();
        let t = &c.code.topology;
        assert_eq!(t.n2(), 3);
        assert_eq!(t.k2, 2);
        assert_eq!(t.group_sizes(), vec![4, 6, 5]);
        assert!(!t.is_uniform_code());
        // Group 0 inherits the global profile.
        assert_eq!(t.groups[0].worker, StragglerModel::exp(10.0));
        assert_eq!(t.groups[0].link, StragglerModel::exp(1.0));
        // Group 1 overrides mu1 and carries a 2x slowdown multiplier.
        assert_eq!(t.groups[1].worker, StragglerModel::exp(2.5));
        assert_eq!(t.groups[1].scale, Some(2.0));
        // Group 2 overrides mu2 and bakes in a dead worker.
        assert_eq!(t.groups[2].link, StragglerModel::exp(4.0));
        assert_eq!(t.groups[2].dead_workers, vec![4]);
        // The built scheme spans the same topology.
        let scheme = c.build_scheme().unwrap();
        assert_eq!(scheme.num_workers(), 15);
        assert_eq!(scheme.topology(), *t);
    }

    #[test]
    fn groups_array_rejects_malformed_inputs() {
        // The groups form needs the hierarchical scheme — even uniform
        // groups, whose per-group profiles the baselines would drop.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "mds", "k2": 1,
                         "groups": [{"n1": 4, "k1": 2}, {"n1": 6, "k1": 3}]}}"#,
        )
        .is_err());
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "product", "k2": 1,
                         "groups": [{"n1": 4, "k1": 2}, {"n1": 4, "k1": 2}]}}"#,
        )
        .is_err());
        // n2 contradicting the group count.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "n2": 3,
                         "groups": [{"n1": 4, "k1": 2}, {"n1": 4, "k1": 2}]}}"#,
        )
        .is_err());
        // A malformed n2 next to groups is rejected, not ignored.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "n2": 2.5,
                         "groups": [{"n1": 4, "k1": 2}, {"n1": 4, "k1": 2}]}}"#,
        )
        .is_err());
        // Uniform sugar and groups are mutually exclusive.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"n1": 4, "k1": 2, "k2": 1,
                         "groups": [{"n1": 4, "k1": 2}]}}"#,
        )
        .is_err());
        // k1 > n1 inside a group.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "groups": [{"n1": 2, "k1": 3}]}}"#,
        )
        .is_err());
        // Dead worker index out of the group's range.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "groups": [{"n1": 3, "k1": 2, "dead_workers": [3]}]}}"#,
        )
        .is_err());
        // Non-positive per-group rate.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "groups": [{"n1": 3, "k1": 2, "mu1": 0}]}}"#,
        )
        .is_err());
        // Non-positive slowdown multiplier.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "groups": [{"n1": 3, "k1": 2, "scale": 0}]}}"#,
        )
        .is_err());
        // Empty groups array.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "groups": []}}"#,
        )
        .is_err());
    }

    #[test]
    fn subtasks_per_worker_parses_uniform_and_per_group() {
        // Uniform sugar: every group inherits r.
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 4, "k1": 2, "n2": 3, "k2": 2,
                         "subtasks_per_worker": 4}}"#,
        )
        .unwrap();
        assert!(c.code.topology.groups.iter().all(|g| g.subtasks == 4));
        assert_eq!(c.code.topology.groups[0].recovery_subresults(), 8);
        // Absent knob: the all-or-nothing default.
        let d = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 4, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .unwrap();
        assert!(d.code.topology.groups.iter().all(|g| g.subtasks == 1));
        // An explicit r = 1 is the exact same topology value as the
        // default — the bit-identity guarantee starts at parse time.
        let e = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 4, "k1": 2, "n2": 3, "k2": 2,
                         "subtasks_per_worker": 1}}"#,
        )
        .unwrap();
        assert_eq!(d.code.topology, e.code.topology);
        // Groups form: the knob is the default, per-group overrides win.
        let g = ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "subtasks_per_worker": 2,
                         "groups": [{"n1": 4, "k1": 2},
                                    {"n1": 4, "k1": 2, "subtasks": 8}]}}"#,
        )
        .unwrap();
        assert_eq!(g.code.topology.groups[0].subtasks, 2);
        assert_eq!(g.code.topology.groups[1].subtasks, 8);
    }

    #[test]
    fn subtasks_per_worker_rejects_degenerate_values() {
        for bad in [
            r#""subtasks_per_worker": 0"#,
            r#""subtasks_per_worker": 2.5"#,
            r#""subtasks_per_worker": "4""#,
            r#""subtasks_per_worker": 65"#,
        ] {
            let text = format!(r#"{{"code": {{"n1": 4, "k1": 2, "n2": 3, "k2": 2, {bad}}}}}"#);
            assert!(
                ClusterConfig::from_json_text(&text).is_err(),
                "must reject: {bad}"
            );
        }
        // Per-group subtasks validated the same way.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"k2": 1, "groups": [{"n1": 3, "k1": 2, "subtasks": 0}]}}"#,
        )
        .is_err());
        // Partial-work mode is hierarchical-only: flat schemes have no
        // per-group inner code to layer sub-tasks on.
        for scheme in ["mds", "product", "replication", "polynomial"] {
            let text = format!(
                r#"{{"code": {{"scheme": "{scheme}", "n1": 4, "k1": 2,
                               "n2": 4, "k2": 2, "subtasks_per_worker": 2}}}}"#
            );
            assert!(
                ClusterConfig::from_json_text(&text).is_err(),
                "{scheme} must reject subtasks_per_worker > 1"
            );
        }
        // r = 1 stays valid for every scheme (the sugar is inert).
        let ok = ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "mds", "n1": 4, "k1": 2, "n2": 4, "k2": 2,
                         "subtasks_per_worker": 1}}"#,
        );
        assert!(ok.is_ok());
    }

    #[test]
    fn malformed_seed_rejected_instead_of_defaulted() {
        for bad in [r#""42""#, "4.5", "true", "-1", "null"] {
            let text = format!(
                r#"{{"code": {{"n1": 3, "k1": 2, "n2": 3, "k2": 2}}, "seed": {bad}}}"#
            );
            assert!(
                ClusterConfig::from_json_text(&text).is_err(),
                "seed {bad} must be rejected, not silently defaulted"
            );
        }
        // A valid integer seed still parses.
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2}, "seed": 9}"#,
        )
        .unwrap();
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .unwrap();
        assert_eq!(c.batching.max_batch, BatchConfig::default().max_batch);
        assert!(c.runtime.use_pjrt);
        assert_eq!(c.seed, 42);
    }

    #[test]
    fn invalid_code_rejected() {
        let bad = r#"{"code": {"n1": 2, "k1": 3, "n2": 3, "k2": 2}}"#;
        assert!(ClusterConfig::from_json_text(bad).is_err());
    }

    #[test]
    fn scheme_field_parsed_and_validated() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "product", "n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .unwrap();
        assert_eq!(c.code.scheme, SchemeKind::Product);
        assert_eq!(c.code.build().unwrap().num_workers(), 9);
        // Unknown scheme name rejected.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "raptor", "n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .is_err());
        // Replication needs k1·k2 | n1·n2: 4 does not divide 9.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "replication", "n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .is_err());
        // …but a 4×4 grid works for every scheme.
        for name in ["hierarchical", "mds", "product", "replication", "polynomial"] {
            let text = format!(
                r#"{{"code": {{"scheme": "{name}", "n1": 4, "k1": 2, "n2": 4, "k2": 2}}}}"#
            );
            let c = ClusterConfig::from_json_text(&text).unwrap();
            assert_eq!(c.code.build().unwrap().num_workers(), 16, "{name}");
        }
    }

    #[test]
    fn decode_threads_validated_and_wired() {
        // 0 = auto is accepted and resolves to >= 1 threads.
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                "runtime": {"decode_threads": 0}}"#,
        )
        .unwrap();
        assert_eq!(c.runtime.decode_threads, 0);
        assert!(c.build_scheme().is_ok());
        // Absurd values are rejected at parse time.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                "runtime": {"decode_threads": 100000}}"#,
        )
        .is_err());
    }

    #[test]
    fn unknown_straggler_model_rejected() {
        let bad = r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                      "straggler": {"model": "pareto", "mu1": 1, "mu2": 1}}"#;
        assert!(ClusterConfig::from_json_text(bad).is_err());
    }

    #[test]
    fn shifted_model_parsed() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                "straggler": {"model": "shifted", "mu1": 5, "mu2": 1, "shift": 0.1}}"#,
        )
        .unwrap();
        assert_eq!(
            c.straggler.worker,
            StragglerModel::ShiftedExponential { shift: 0.1, mu: 5.0 }
        );
    }

    #[test]
    fn serving_section_parsed_with_model_table() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                "serving": {"queue_cap": 8, "default_deadline_ms": 250.0,
                            "drain_ms": 1000,
                            "models": [
                              {"name": "a", "rows": 8, "cols": 4},
                              {"name": "b", "rows": 16, "cols": 2, "seed": 7}
                            ]}}"#,
        )
        .unwrap();
        assert_eq!(c.serving.queue_cap, 8);
        assert_eq!(c.serving.default_deadline_ms, 250.0);
        assert_eq!(c.serving.drain_ms, 1000.0);
        assert_eq!(c.serving.models.len(), 2);
        assert_eq!(c.serving.models[0].name, "a");
        assert_eq!(c.serving.models[0].seed, 1, "index-derived default seed");
        assert_eq!(c.serving.models[1].seed, 7);
        // Absent section: defaults.
        let d = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .unwrap();
        assert_eq!(d.serving, ServingConfig::default());
    }

    #[test]
    fn serving_rejects_degenerate_values_at_parse_time() {
        for bad in [
            // Zero / malformed admission parameters.
            r#""serving": {"queue_cap": 0}"#,
            r#""serving": {"queue_cap": 2.5}"#,
            r#""serving": {"default_deadline_ms": 0}"#,
            r#""serving": {"default_deadline_ms": -5}"#,
            r#""serving": {"default_deadline_ms": true}"#,
            r#""serving": {"drain_ms": 0}"#,
            // Model-table mistakes.
            r#""serving": {"models": [{"name": "a", "rows": 8, "cols": 4},
                                      {"name": "a", "rows": 8, "cols": 4}]}"#,
            r#""serving": {"models": [{"name": "", "rows": 8, "cols": 4}]}"#,
            r#""serving": {"models": [{"name": "a", "rows": 0, "cols": 4}]}"#,
            r#""serving": {"models": [{"name": "a", "rows": 8, "cols": 0}]}"#,
            r#""serving": {"models": [{"rows": 8, "cols": 4}]}"#,
            r#""serving": {"models": [{"name": "a", "rows": 8, "cols": 4,
                                       "seed": "x"}]}"#,
            r#""serving": {"models": {"name": "a"}}"#,
        ] {
            let text = format!(
                r#"{{"code": {{"n1": 3, "k1": 2, "n2": 3, "k2": 2}}, {bad}}}"#
            );
            assert!(
                ClusterConfig::from_json_text(&text).is_err(),
                "must reject: {bad}"
            );
        }
    }

    #[test]
    fn zero_batch_rejected() {
        let bad = r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                      "batching": {"max_batch": 0}}"#;
        assert!(ClusterConfig::from_json_text(bad).is_err());
    }
}
