//! Typed configuration schema with validation.
//!
//! A deployment is described by a JSON document:
//!
//! ```json
//! {
//!   "code":      {"scheme": "hierarchical",
//!                 "n1": 4, "k1": 2, "n2": 4, "k2": 2},
//!   "straggler": {"model": "exponential", "mu1": 10.0, "mu2": 1.0,
//!                 "scale": 0.02},
//!   "runtime":   {"artifact_dir": "artifacts", "use_pjrt": true,
//!                 "decode_threads": 4},
//!   "batching":  {"max_batch": 8, "max_wait_ms": 5.0}
//! }
//! ```
//!
//! `code.scheme` selects the coding scheme the cluster runs
//! (`hierarchical | mds | product | replication | polynomial`, default
//! `hierarchical`). Grid schemes use `(n1,k1)×(n2,k2)` directly; flat
//! schemes use `n = n1·n2`, `k = k1·k2` so every scheme deploys the
//! same worker count and recovery threshold (§IV's comparison).

use crate::coding::hierarchical::HierarchicalParams;
use crate::coding::{build_scheme, CodedScheme, SchemeKind};
use crate::config::json::Json;
use crate::sim::straggler::StragglerModel;
use crate::{Error, Result};
use std::sync::Arc;

/// The coding-scheme selection plus `(n1,k1)×(n2,k2)` grid parameters.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CodeConfig {
    /// Which scheme the cluster runs.
    pub scheme: SchemeKind,
    /// Workers per group.
    pub n1: usize,
    /// Inner code dimension.
    pub k1: usize,
    /// Number of groups.
    pub n2: usize,
    /// Outer code dimension.
    pub k2: usize,
}

impl CodeConfig {
    /// Parse from the `"code"` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        let scheme = match v.get("scheme").and_then(|s| s.as_str()) {
            Some(name) => SchemeKind::parse(name)?,
            None => SchemeKind::Hierarchical,
        };
        let c = Self {
            scheme,
            n1: v.req_usize("n1", "code")?,
            k1: v.req_usize("k1", "code")?,
            n2: v.req_usize("n2", "code")?,
            k2: v.req_usize("k2", "code")?,
        };
        c.validate()?;
        Ok(c)
    }

    /// Validate the parameters for the selected scheme.
    pub fn validate(&self) -> Result<()> {
        let (n, k) = (self.n1 * self.n2, self.k1 * self.k2);
        match self.scheme {
            SchemeKind::Hierarchical | SchemeKind::Product => self.to_params().validate(),
            SchemeKind::Mds | SchemeKind::Polynomial => {
                if k == 0 || k > n {
                    return Err(Error::InvalidParams(format!(
                        "{}: need 1 <= k1·k2 <= n1·n2, got ({n}, {k})",
                        self.scheme
                    )));
                }
                Ok(())
            }
            SchemeKind::Replication => {
                if k == 0 || k > n || n % k != 0 {
                    return Err(Error::InvalidParams(format!(
                        "replication: need k1·k2 ({k}) dividing n1·n2 ({n})"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Build the configured scheme.
    pub fn build(&self) -> Result<Arc<dyn CodedScheme>> {
        build_scheme(self.scheme, self.n1, self.k1, self.n2, self.k2)
    }

    /// Convert to [`HierarchicalParams`] (homogeneous) — meaningful for
    /// the grid schemes.
    pub fn to_params(&self) -> HierarchicalParams {
        HierarchicalParams::homogeneous(self.n1, self.k1, self.n2, self.k2)
    }
}

/// Straggler-injection configuration for the in-process cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerConfig {
    /// Worker compute-delay model.
    pub worker: StragglerModel,
    /// Group→master link-delay model.
    pub link: StragglerModel,
    /// Wall-clock seconds per model time unit (the paper's µ are in
    /// abstract time units; `scale` maps them onto real sleeps).
    pub scale: f64,
    /// Whether delays are injected at all (off for pure-throughput runs).
    pub enabled: bool,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        Self {
            worker: StragglerModel::exp(10.0),
            link: StragglerModel::exp(1.0),
            scale: 0.01,
            enabled: true,
        }
    }
}

impl StragglerConfig {
    /// Parse from the `"straggler"` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        let model = v
            .get("model")
            .and_then(|m| m.as_str())
            .unwrap_or("exponential")
            .to_string();
        let mu1 = v.req_f64("mu1", "straggler")?;
        let mu2 = v.req_f64("mu2", "straggler")?;
        if mu1 <= 0.0 || mu2 <= 0.0 {
            return Err(Error::Config("straggler rates must be positive".into()));
        }
        let (worker, link) = match model.as_str() {
            "exponential" => (StragglerModel::exp(mu1), StragglerModel::exp(mu2)),
            "shifted" => {
                let shift = v.req_f64("shift", "straggler")?;
                (
                    StragglerModel::ShiftedExponential { shift, mu: mu1 },
                    StragglerModel::exp(mu2),
                )
            }
            "deterministic" => (
                StragglerModel::Deterministic { value: 1.0 / mu1 },
                StragglerModel::Deterministic { value: 1.0 / mu2 },
            ),
            other => {
                return Err(Error::Config(format!(
                    "unknown straggler model '{other}' (expected exponential|shifted|deterministic)"
                )))
            }
        };
        Ok(Self {
            worker,
            link,
            scale: v.get("scale").and_then(|s| s.as_f64()).unwrap_or(0.01),
            enabled: v.get("enabled").and_then(|e| e.as_bool()).unwrap_or(true),
        })
    }
}

/// PJRT runtime configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RuntimeConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifact_dir: String,
    /// Execute worker products through PJRT (false = pure-Rust fallback,
    /// used by tests that must run without artifacts).
    pub use_pjrt: bool,
    /// Width of the decode pool every decoder session fans across:
    /// group eliminations and the multi-RHS solve's column panels.
    /// `0` = all available cores; values above
    /// [`crate::parallel::MAX_THREADS`] are rejected at parse time.
    pub decode_threads: usize,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self {
            artifact_dir: "artifacts".to_string(),
            use_pjrt: true,
            decode_threads: 4,
        }
    }
}

impl RuntimeConfig {
    /// Parse from the `"runtime"` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let decode_threads = v
            .get("decode_threads")
            .and_then(|t| t.as_usize())
            .unwrap_or(d.decode_threads);
        if decode_threads > crate::parallel::MAX_THREADS {
            return Err(Error::Config(format!(
                "runtime.decode_threads = {decode_threads} exceeds the {} ceiling \
                 (use 0 for all available cores)",
                crate::parallel::MAX_THREADS
            )));
        }
        Ok(Self {
            artifact_dir: v
                .get("artifact_dir")
                .and_then(|a| a.as_str())
                .unwrap_or(&d.artifact_dir)
                .to_string(),
            use_pjrt: v.get("use_pjrt").and_then(|u| u.as_bool()).unwrap_or(d.use_pjrt),
            decode_threads,
        })
    }
}

/// Request batching policy.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchConfig {
    /// Maximum requests folded into one coded job.
    pub max_batch: usize,
    /// Maximum time the batcher holds a request open (milliseconds).
    pub max_wait_ms: f64,
}

impl Default for BatchConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait_ms: 5.0,
        }
    }
}

impl BatchConfig {
    /// Parse from the `"batching"` object.
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let c = Self {
            max_batch: v.get("max_batch").and_then(|b| b.as_usize()).unwrap_or(d.max_batch),
            max_wait_ms: v
                .get("max_wait_ms")
                .and_then(|w| w.as_f64())
                .unwrap_or(d.max_wait_ms),
        };
        if c.max_batch == 0 {
            return Err(Error::Config("max_batch must be >= 1".into()));
        }
        Ok(c)
    }
}

/// Full cluster configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Code parameters.
    pub code: CodeConfig,
    /// Straggler injection.
    pub straggler: StragglerConfig,
    /// Runtime / artifacts.
    pub runtime: RuntimeConfig,
    /// Batching policy.
    pub batching: BatchConfig,
    /// RNG seed for straggler injection.
    pub seed: u64,
}

impl ClusterConfig {
    /// Build the configured scheme with `runtime.decode_threads` wired
    /// into its decode pool — the one construction path the live
    /// cluster uses, so the config field actually drives the decoders.
    pub fn build_scheme(&self) -> Result<Arc<dyn CodedScheme>> {
        crate::coding::build_scheme_with(
            self.code.scheme,
            self.code.n1,
            self.code.k1,
            self.code.n2,
            self.code.k2,
            self.runtime.decode_threads,
        )
    }

    /// Parse a full config document.
    pub fn from_json_text(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let code = CodeConfig::from_json(v.req("code", "config")?)?;
        let straggler = match v.get("straggler") {
            Some(s) => StragglerConfig::from_json(s)?,
            None => StragglerConfig::default(),
        };
        let runtime = match v.get("runtime") {
            Some(r) => RuntimeConfig::from_json(r)?,
            None => RuntimeConfig::default(),
        };
        let batching = match v.get("batching") {
            Some(b) => BatchConfig::from_json(b)?,
            None => BatchConfig::default(),
        };
        let seed = v.get("seed").and_then(|s| s.as_usize()).unwrap_or(42) as u64;
        Ok(Self {
            code,
            straggler,
            runtime,
            batching,
            seed,
        })
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {path}: {e}")))?;
        Self::from_json_text(&text)
    }

    /// A small test/demo config (no PJRT required) for any scheme.
    pub fn demo_scheme(scheme: SchemeKind, n1: usize, k1: usize, n2: usize, k2: usize) -> Self {
        let mut c = Self::demo(n1, k1, n2, k2);
        c.code.scheme = scheme;
        c
    }

    /// A small test/demo config (no PJRT required), hierarchical.
    pub fn demo(n1: usize, k1: usize, n2: usize, k2: usize) -> Self {
        Self {
            code: CodeConfig {
                scheme: SchemeKind::Hierarchical,
                n1,
                k1,
                n2,
                k2,
            },
            straggler: StragglerConfig {
                scale: 0.001,
                ..StragglerConfig::default()
            },
            runtime: RuntimeConfig {
                use_pjrt: false,
                decode_threads: 2,
                ..RuntimeConfig::default()
            },
            batching: BatchConfig::default(),
            seed: 42,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"{
        "code": {"n1": 4, "k1": 2, "n2": 3, "k2": 2},
        "straggler": {"model": "exponential", "mu1": 10.0, "mu2": 1.0,
                      "scale": 0.02, "enabled": true},
        "runtime": {"artifact_dir": "artifacts", "use_pjrt": false,
                    "decode_threads": 3},
        "batching": {"max_batch": 4, "max_wait_ms": 2.5},
        "seed": 7
    }"#;

    #[test]
    fn parses_full_config() {
        let c = ClusterConfig::from_json_text(FULL).unwrap();
        assert_eq!(
            c.code,
            CodeConfig {
                scheme: SchemeKind::Hierarchical,
                n1: 4,
                k1: 2,
                n2: 3,
                k2: 2
            }
        );
        assert_eq!(c.runtime.decode_threads, 3);
        assert!(!c.runtime.use_pjrt);
        assert_eq!(c.batching.max_batch, 4);
        assert_eq!(c.seed, 7);
        assert!(c.straggler.enabled);
        assert_eq!(c.straggler.scale, 0.02);
    }

    #[test]
    fn defaults_fill_missing_sections() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .unwrap();
        assert_eq!(c.batching.max_batch, BatchConfig::default().max_batch);
        assert!(c.runtime.use_pjrt);
        assert_eq!(c.seed, 42);
    }

    #[test]
    fn invalid_code_rejected() {
        let bad = r#"{"code": {"n1": 2, "k1": 3, "n2": 3, "k2": 2}}"#;
        assert!(ClusterConfig::from_json_text(bad).is_err());
    }

    #[test]
    fn scheme_field_parsed_and_validated() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "product", "n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .unwrap();
        assert_eq!(c.code.scheme, SchemeKind::Product);
        assert_eq!(c.code.build().unwrap().num_workers(), 9);
        // Unknown scheme name rejected.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "raptor", "n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .is_err());
        // Replication needs k1·k2 | n1·n2: 4 does not divide 9.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"scheme": "replication", "n1": 3, "k1": 2, "n2": 3, "k2": 2}}"#,
        )
        .is_err());
        // …but a 4×4 grid works for every scheme.
        for name in ["hierarchical", "mds", "product", "replication", "polynomial"] {
            let text = format!(
                r#"{{"code": {{"scheme": "{name}", "n1": 4, "k1": 2, "n2": 4, "k2": 2}}}}"#
            );
            let c = ClusterConfig::from_json_text(&text).unwrap();
            assert_eq!(c.code.build().unwrap().num_workers(), 16, "{name}");
        }
    }

    #[test]
    fn decode_threads_validated_and_wired() {
        // 0 = auto is accepted and resolves to >= 1 threads.
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                "runtime": {"decode_threads": 0}}"#,
        )
        .unwrap();
        assert_eq!(c.runtime.decode_threads, 0);
        assert!(c.build_scheme().is_ok());
        // Absurd values are rejected at parse time.
        assert!(ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                "runtime": {"decode_threads": 100000}}"#,
        )
        .is_err());
    }

    #[test]
    fn unknown_straggler_model_rejected() {
        let bad = r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                      "straggler": {"model": "pareto", "mu1": 1, "mu2": 1}}"#;
        assert!(ClusterConfig::from_json_text(bad).is_err());
    }

    #[test]
    fn shifted_model_parsed() {
        let c = ClusterConfig::from_json_text(
            r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                "straggler": {"model": "shifted", "mu1": 5, "mu2": 1, "shift": 0.1}}"#,
        )
        .unwrap();
        assert_eq!(
            c.straggler.worker,
            StragglerModel::ShiftedExponential { shift: 0.1, mu: 5.0 }
        );
    }

    #[test]
    fn zero_batch_rejected() {
        let bad = r#"{"code": {"n1": 3, "k1": 2, "n2": 3, "k2": 2},
                      "batching": {"max_batch": 0}}"#;
        assert!(ClusterConfig::from_json_text(bad).is_err());
    }
}
