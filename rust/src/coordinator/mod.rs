//! The runnable system: an in-process hierarchical coded-computation
//! cluster (Fig. 1's topology as threads + channels).
//!
//! ```text
//!  client ─▶ Batcher ─▶ Master ──▶ Submaster(1) ──▶ Worker(1,1..n1)
//!    ▲          │          │  └──▶ Submaster(…) ──▶ Worker(…)
//!    └──────────┴──results─┘       (intra-group decode at k1-th
//!                                   result, uplink to master)
//! ```
//!
//! * [`batcher`] — folds incoming requests into batched jobs (`X` with
//!   up to `max_batch` columns) so worker products feed MXU-shaped
//!   artifacts;
//! * [`backend`] — the worker's compute: PJRT artifact execution or the
//!   pure-Rust fallback;
//! * [`worker`] — one thread per `w(i,j)`: straggler-delay injection,
//!   shard product, result upload;
//! * [`submaster`] — one thread per group: collects the `k1` fastest,
//!   intra-group decode, uplink (with ToR delay) to the master;
//! * [`master`] — job state machine: collects the `k2` fastest groups,
//!   cross-group decode, response fan-out;
//! * [`cluster`] — the public facade: [`cluster::Cluster::launch`],
//!   [`cluster::Cluster::submit`], metrics, shutdown;
//! * [`metrics`] — counters and latency histograms;
//! * [`fault`] — failure injection (dead workers / severed uplinks).
//!
//! Python never appears here: workers execute AOT artifacts through
//! [`crate::runtime`], everything else is Rust.

pub mod backend;
pub mod batcher;
pub mod cluster;
pub mod fault;
pub mod master;
pub mod messages;
pub mod metrics;
pub mod submaster;
pub mod worker;

pub use cluster::{Cluster, JobHandle};
pub use messages::{JobId, JobRequest};
