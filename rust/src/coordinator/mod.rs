//! The runnable system: an in-process coded-computation cluster
//! (Fig. 1's topology as threads + channels), generic over the coding
//! scheme.
//!
//! ```text
//!  client ─▶ Batcher ─▶ Master ──▶ Submaster(1) ──▶ Worker(1,1..n1)
//!    ▲          │          │  └──▶ Submaster(…) ──▶ Worker(…)
//!    └──────────┴──results─┘       (group decode session at k1-th
//!                                   result, or raw-product relay)
//! ```
//!
//! Decoding runs through the streaming [`crate::coding::Decoder`]
//! sessions: submasters of schemes with splittable decodes
//! (hierarchical) finish their group session at the `k1`-th product and
//! ship the partial; the master feeds partials into its own session and
//! replies the instant it turns `Ready`.
//!
//! * [`batcher`] — folds incoming requests into batched jobs (`X` with
//!   up to `max_batch` columns) so worker products feed MXU-shaped
//!   artifacts;
//! * [`backend`] — the worker's compute: PJRT artifact execution or the
//!   pure-Rust fallback;
//! * [`worker`] — one thread per `w(i,j)`: straggler-delay injection,
//!   shard product, result upload;
//! * [`submaster`] — one thread per group: group decode session or
//!   relay, uplink (with ToR delay) to the master;
//! * [`master`] — job state machine: one decode session per job,
//!   response fan-out, job cancellation, shutdown drain;
//! * [`cluster`] — the serving API: an owning [`cluster::ClusterCore`]
//!   (thread tree + runtime model registry) and cheap cloneable
//!   [`cluster::ClientHandle`]s with per-submission
//!   [`cluster::SubmitOptions`] and bounded-queue admission control
//!   ([`crate::Error::Busy`] backpressure, deadline shedding); plus the
//!   single-tenant [`cluster::Cluster`] facade;
//! * [`metrics`] — counters, admission gauges, liveness gauges and
//!   latency histograms (p50/p95/p99);
//! * [`fault`] — the fault model: launch-time [`fault::FaultConfig`],
//!   the live [`fault::FaultState`] switchboard every thread consults,
//!   and seeded timed [`fault::FaultPlan`] schedules;
//! * [`chaos`] — robustness machinery: the failure detector the master
//!   runs over heartbeat streams, the [`chaos::FaultInjector`] surface
//!   the cluster supervisor implements, and the driver thread that
//!   replays a `FaultPlan` against it.
//!
//! Python never appears here: workers execute AOT artifacts through
//! [`crate::runtime`], everything else is Rust.

pub mod backend;
pub mod batcher;
pub mod chaos;
pub mod cluster;
pub mod fault;
pub mod master;
pub mod messages;
pub mod metrics;
pub mod submaster;
pub mod worker;

pub use cluster::{
    ClientHandle, Cluster, ClusterCore, DEFAULT_MODEL, JobHandle, SubmitOptions,
};
pub use messages::{JobId, JobRequest, ModelId, RequestId};
