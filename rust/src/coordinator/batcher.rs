//! Request batcher: folds client requests into batched coded jobs —
//! one **lane per model**, since requests for different models can
//! never share a job.
//!
//! Each lane waits up to `max_wait_ms` for up to `max_batch` requests,
//! stacks their vectors into one `d × b` matrix `X`, pads `b` up to a
//! batch width the backend's artifact set supports for that model's
//! shard shape (extra columns are zero and sliced off at reply
//! fan-out), and hands the job to the master. One coded job then serves
//! the whole batch — amortizing straggler waits, decodes and PJRT
//! dispatches across requests, and shaping worker GEMMs for the MXU
//! (DESIGN.md §Hardware-Adaptation).
//!
//! The batcher is also half of admission control: it releases each
//! request's queue reservation (`ModelEntry::queued` and the global
//! `queue_depth` gauge) when the request leaves the queue — dispatched
//! into a job, or **shed** with [`JobError::Deadline`] if its admission
//! deadline expired while it waited. Within a flush, higher
//! [`JobRequest::priority`] dispatches first (FIFO within a class).
//!
//! On channel close (all client senders gone — `shutdown` took the
//! service's sender) the batcher flushes every lane's tail and sends
//! [`MasterMsg::Drain`] behind the last batch, handing the master the
//! drain baton.

use crate::config::schema::BatchConfig;
use crate::coordinator::messages::{
    JobBroadcast, JobError, JobId, JobRequest, MasterMsg, ModelEntry, ModelId,
    ReplyRoute,
};
use crate::coordinator::metrics::Metrics;
use crate::linalg::Matrix;
use crate::sync::{Condvar, Mutex};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// One model's open batch window.
struct Lane {
    reqs: Vec<JobRequest>,
    /// When this lane flushes even if under-full.
    window: Instant,
}

/// How often the batcher wakes to observe the pause flag (and, while
/// idle, live knob changes). Bounded so [`BatcherControl::pause`] is
/// acknowledged promptly even when no requests flow.
const PAUSE_POLL: Duration = Duration::from_millis(20);

/// Live batching knobs plus the rollout pause gate, shared between the
/// batcher thread and the control plane.
///
/// `pause` is the first step of a heavy rollout's quiesce: a paused
/// batcher keeps *accepting* requests (they buffer in their lanes,
/// admission-bounded as always) but dispatches no new `Batch` to the
/// master — so once the master's in-flight set drains to zero it stays
/// zero until [`BatcherControl::resume`]. The knobs (`max_batch`,
/// `max_wait_us`) are read by the batcher on every flush decision, so
/// a light rollout retunes batching without touching the thread.
#[derive(Debug)]
pub struct BatcherControl {
    paused: AtomicBool,
    /// Set by the batcher once it has *observed* the pause — the
    /// handshake `pause()` waits on, so callers know no further batch
    /// can be racing toward the master.
    ack: Mutex<bool>,
    ack_cv: Condvar,
    max_batch: AtomicUsize,
    max_wait_us: AtomicU64,
}

impl BatcherControl {
    fn new(config: &BatchConfig) -> Self {
        Self {
            paused: AtomicBool::new(false),
            ack: Mutex::new(false),
            ack_cv: Condvar::new(),
            max_batch: AtomicUsize::new(config.max_batch),
            max_wait_us: AtomicU64::new((config.max_wait_ms * 1e3).max(0.0) as u64),
        }
    }

    /// Retune the batching knobs live (light rollout path).
    pub fn set_batching(&self, max_batch: usize, max_wait_ms: f64) {
        self.max_batch.store(max_batch.max(1), Ordering::Relaxed);
        self.max_wait_us
            .store((max_wait_ms * 1e3).max(0.0) as u64, Ordering::Relaxed);
    }

    /// Current per-lane window length.
    fn max_wait(&self) -> Duration {
        Duration::from_micros(self.max_wait_us.load(Ordering::Relaxed))
    }

    /// Stop dispatching batches and wait until the batcher acknowledges
    /// (bounded by `timeout`). Returns whether the ack arrived — on
    /// `false` the caller must *not* assume quiescence and should
    /// [`BatcherControl::resume`] immediately.
    pub fn pause(&self, timeout: Duration) -> bool {
        {
            let mut acked = self.ack.lock();
            *acked = false;
        }
        self.paused.store(true, Ordering::Release);
        let deadline = Instant::now() + timeout;
        let mut acked = self.ack.lock();
        loop {
            if *acked {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.ack_cv.wait_timeout(acked, deadline - now);
            acked = guard;
        }
    }

    /// Resume dispatching. Buffered lanes flush on their (already
    /// elapsed) windows within one poll cadence.
    pub fn resume(&self) {
        self.paused.store(false, Ordering::Release);
    }
}

/// Spawn the batcher thread. Returns the join handle plus the shared
/// [`BatcherControl`] the control plane uses to pause dispatch and
/// retune the knobs live. Errors only if the OS refuses to spawn the
/// thread.
pub fn spawn(
    config: BatchConfig,
    metrics: Arc<Metrics>,
    rx: mpsc::Receiver<JobRequest>,
    master: mpsc::Sender<MasterMsg>,
) -> crate::Result<(thread::JoinHandle<()>, Arc<BatcherControl>)> {
    let ctrl = Arc::new(BatcherControl::new(&config));
    let thread_ctrl = Arc::clone(&ctrl);
    let handle = thread::Builder::new()
        .name("hiercode-batcher".to_string())
        .spawn(move || {
            let ctrl = thread_ctrl;
            let mut next_id = 0u64;
            let mut lanes: HashMap<ModelId, Lane> = HashMap::new();
            loop {
                let paused = ctrl.paused.load(Ordering::Acquire);
                if paused {
                    // Acknowledge exactly once per pause: after this,
                    // no further Batch leaves until resume.
                    let mut acked = ctrl.ack.lock();
                    if !*acked {
                        *acked = true;
                        ctrl.ack_cv.notify_all();
                    }
                }
                // Wait for the next request — but never longer than the
                // poll cadence (the pause flag must be observed even on
                // a quiet service), nor past the earliest lane window.
                let mut timeout = PAUSE_POLL;
                if !paused {
                    if let Some(dl) = lanes.values().map(|l| l.window).min() {
                        let now = Instant::now();
                        timeout = if now >= dl {
                            Duration::ZERO
                        } else {
                            PAUSE_POLL.min(dl - now)
                        };
                    }
                }
                let msg = if timeout.is_zero() {
                    None
                } else {
                    match rx.recv_timeout(timeout) {
                        Ok(m) => Some(m),
                        Err(mpsc::RecvTimeoutError::Timeout) => None,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                };
                match msg {
                    Some(req) => {
                        let model = req.entry.id;
                        let cap = effective_max_batch(
                            ctrl.max_batch.load(Ordering::Relaxed),
                            req.entry.supported_widths.as_deref(),
                        );
                        let max_wait = ctrl.max_wait();
                        let lane = lanes.entry(model).or_insert_with(|| Lane {
                            reqs: Vec::new(),
                            window: Instant::now() + max_wait,
                        });
                        lane.reqs.push(req);
                        if !paused && lane.reqs.len() >= cap {
                            // The lane was inserted just above, so this
                            // always takes the Some arm — written as
                            // if-let so a (impossible) miss degrades to
                            // a late window flush, not a panic.
                            if let Some(mut lane) = lanes.remove(&model) {
                                flush(
                                    &mut lane.reqs,
                                    &mut next_id,
                                    &ctrl,
                                    &metrics,
                                    &master,
                                );
                            }
                        }
                    }
                    None => {
                        if paused {
                            // Windows stay due while paused; they flush
                            // within one poll of resume.
                            continue;
                        }
                        // A window deadline hit: flush every due lane.
                        let now = Instant::now();
                        let due: Vec<ModelId> = lanes
                            .iter()
                            .filter(|(_, l)| l.window <= now)
                            .map(|(&m, _)| m)
                            .collect();
                        for model in due {
                            // `due` was computed from the same map one
                            // statement ago; if-let instead of expect so
                            // a stale id is a no-op, not a panic.
                            if let Some(mut lane) = lanes.remove(&model) {
                                flush(
                                    &mut lane.reqs,
                                    &mut next_id,
                                    &ctrl,
                                    &metrics,
                                    &master,
                                );
                            }
                        }
                    }
                }
            }
            // Channel closed (shutdown): flush every tail, then hand
            // the master the drain baton — behind the last batch, so
            // nothing accepted is ever dropped. Deliberately ignores a
            // pause: shutdown's drain supersedes any rollout in flight.
            for (_, mut lane) in lanes.drain() {
                flush(&mut lane.reqs, &mut next_id, &ctrl, &metrics, &master);
            }
            let _ = master.send(MasterMsg::Drain);
        })?;
    Ok((handle, ctrl))
}

/// Cap the configured batch size at the largest width the artifact set
/// can serve.
pub fn effective_max_batch(configured: usize, supported: Option<&[usize]>) -> usize {
    match supported {
        None => configured,
        Some(ws) => {
            let max_w = ws.iter().copied().max().unwrap_or(1);
            configured.min(max_w).max(1)
        }
    }
}

/// Release one request's admission reservation.
fn release(metrics: &Metrics, entry: &ModelEntry) {
    Metrics::dec(&metrics.queue_depth);
    entry.admission.release();
}

/// Flush one lane: shed expired requests, order by priority, dispatch
/// the rest in `≤ effective_max_batch` chunks.
fn flush(
    reqs: &mut Vec<JobRequest>,
    next_id: &mut u64,
    ctrl: &BatcherControl,
    metrics: &Metrics,
    master: &mpsc::Sender<MasterMsg>,
) {
    if reqs.is_empty() {
        return;
    }
    // Deadline shedding: expired requests leave the queue here, with an
    // explicit error — never silently buffered.
    let now = Instant::now();
    let mut kept: Vec<JobRequest> = Vec::with_capacity(reqs.len());
    for req in reqs.drain(..) {
        if req.deadline <= now {
            // The queue reservation is released exactly once — here,
            // where the request leaves the queue…
            release(metrics, &req.entry);
            // …while shed *accounting* keys on the winning slot write,
            // so a request can never be counted shed twice (batcher vs
            // master — idempotent-shed invariant).
            if req.slot.complete(Err(JobError::Deadline)) {
                Metrics::inc(&metrics.shed);
                Metrics::inc(&req.entry.shed);
            }
        } else {
            kept.push(req);
        }
    }
    // Higher priority dispatches first; the sort is stable, so equal
    // priorities keep submit order.
    kept.sort_by_key(|r| std::cmp::Reverse(r.priority));
    while !kept.is_empty() {
        let entry = Arc::clone(&kept[0].entry);
        let cap = effective_max_batch(
            ctrl.max_batch.load(Ordering::Relaxed),
            entry.supported_widths.as_deref(),
        );
        let take = cap.min(kept.len());
        let chunk: Vec<JobRequest> = kept.drain(..take).collect();
        dispatch(chunk, &entry, next_id, metrics, master);
    }
}

/// Turn one chunk of same-model requests into a batched job.
fn dispatch(
    chunk: Vec<JobRequest>,
    entry: &Arc<ModelEntry>,
    next_id: &mut u64,
    metrics: &Metrics,
    master: &mpsc::Sender<MasterMsg>,
) {
    let b = chunk.len();
    let width = match crate::coordinator::backend::pick_batch_width(
        entry.supported_widths.as_deref(),
        b,
    ) {
        Ok(w) => w,
        Err(e) => {
            for req in chunk {
                release(metrics, &req.entry);
                req.slot.complete(Err(JobError::Failed(format!("{e}"))));
            }
            return;
        }
    };
    // Stack request vectors into X (d × width), zero-padded.
    let mut x = Matrix::zeros(entry.d, width);
    let mut replies = Vec::with_capacity(b);
    for (col, req) in chunk.into_iter().enumerate() {
        for (row, &v) in req.x.iter().enumerate() {
            x[(row, col)] = v;
        }
        release(metrics, &req.entry);
        replies.push(ReplyRoute {
            entry: Arc::clone(&req.entry),
            slot: req.slot,
            column: col,
            submitted_at: req.submitted_at,
            deadline: req.deadline,
            req_id: req.req_id,
        });
    }
    let id = JobId(*next_id);
    *next_id += 1;
    let _ = master.send(MasterMsg::Batch {
        job: JobBroadcast {
            id,
            model: entry.id,
            out_rows: entry.m,
            x: Arc::new(x),
        },
        replies,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{CompletionSlot, ModelId, RequestId};
    use std::sync::atomic::Ordering;

    fn mk_entry(d: usize, widths: Option<Vec<usize>>) -> Arc<ModelEntry> {
        Arc::new(ModelEntry::new(ModelId(0), "default", d, 4 * d, 1024, widths))
    }

    fn mk_entry_id(id: u32, d: usize) -> Arc<ModelEntry> {
        Arc::new(ModelEntry::new(
            ModelId(id),
            &format!("m{id}"),
            d,
            4 * d,
            1024,
            None,
        ))
    }

    fn mk_request(
        entry: &Arc<ModelEntry>,
        v: f64,
        req: u64,
    ) -> (JobRequest, Arc<CompletionSlot>) {
        let slot = Arc::new(CompletionSlot::new());
        (
            JobRequest {
                entry: Arc::clone(entry),
                x: vec![v; entry.d],
                slot: Arc::clone(&slot),
                submitted_at: Instant::now(),
                deadline: Instant::now() + Duration::from_secs(60),
                priority: 0,
                req_id: RequestId(req),
            },
            slot,
        )
    }

    fn recv_batch(master_rx: &mpsc::Receiver<MasterMsg>) -> (JobBroadcast, Vec<ReplyRoute>) {
        loop {
            match master_rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                MasterMsg::Batch { job, replies } => return (job, replies),
                MasterMsg::Drain => continue,
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 2,
                max_wait_ms: 10_000.0, // deadline never fires in this test
            },
            metrics,
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(3, None);
        let (r1, _s1) = mk_request(&entry, 1.0, 0);
        let (r2, _s2) = mk_request(&entry, 2.0, 1);
        req_tx.send(r1).unwrap();
        req_tx.send(r2).unwrap();
        let (job, replies) = recv_batch(&master_rx);
        assert_eq!(job.x.shape(), (3, 2));
        assert_eq!(job.out_rows, 12);
        assert_eq!(job.model, entry.id);
        assert_eq!(job.x[(0, 0)], 1.0);
        assert_eq!(job.x[(0, 1)], 2.0);
        assert_eq!(replies.len(), 2);
        assert_eq!(replies[1].column, 1);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 100,
                max_wait_ms: 20.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(2, None);
        let (r1, _s1) = mk_request(&entry, 5.0, 0);
        req_tx.send(r1).unwrap();
        let t0 = Instant::now();
        let (job, replies) = recv_batch(&master_rx);
        assert!(t0.elapsed() >= Duration::from_millis(10));
        assert_eq!(job.x.shape(), (2, 1));
        assert_eq!(replies.len(), 1);
    }

    #[test]
    fn pads_to_supported_width() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 3,
                max_wait_ms: 20.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(2, Some(vec![1, 4, 8]));
        for (i, v) in [1.0, 2.0, 3.0].into_iter().enumerate() {
            let (r, _s) = mk_request(&entry, v, i as u64);
            req_tx.send(r).unwrap();
        }
        let (job, replies) = recv_batch(&master_rx);
        // 3 requests padded to width 4.
        assert_eq!(job.x.shape(), (2, 4));
        assert_eq!(job.x[(0, 3)], 0.0, "pad column must be zero");
        assert_eq!(replies.len(), 3);
    }

    #[test]
    fn effective_max_batch_caps_at_artifact_width() {
        assert_eq!(effective_max_batch(16, Some(&[1, 4, 8])), 8);
        assert_eq!(effective_max_batch(4, Some(&[1, 4, 8])), 4);
        assert_eq!(effective_max_batch(16, None), 16);
        // Exact cap match.
        assert_eq!(effective_max_batch(8, Some(&[1, 4, 8])), 8);
        // Degenerate width set still yields a usable batch of 1.
        assert_eq!(effective_max_batch(16, Some(&[])), 1);
        // A width set whose max is below every batch still clamps to it.
        assert_eq!(effective_max_batch(100, Some(&[2])), 2);
    }

    #[test]
    fn pad_width_selection_edge_cases() {
        use crate::coordinator::backend::pick_batch_width;
        // Exact match: no padding.
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 4).unwrap(), 4);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 1).unwrap(), 1);
        // Smallest larger supported width.
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 2).unwrap(), 4);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 3).unwrap(), 4);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 5).unwrap(), 8);
        // No supported width >= b: a Runtime error naming the problem.
        assert!(pick_batch_width(Some(&[1, 4, 8]), 9).is_err());
        assert!(pick_batch_width(Some(&[]), 1).is_err());
        // Native backend serves any width verbatim.
        assert_eq!(pick_batch_width(None, 17).unwrap(), 17);
    }

    #[test]
    fn batcher_pads_single_request_to_smallest_supported_width() {
        // Artifact set without width 1: a lone request rides a width-4
        // job whose pad columns are zero.
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 4,
                max_wait_ms: 10.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(2, Some(vec![4, 8]));
        let (r, _s) = mk_request(&entry, 9.0, 0);
        req_tx.send(r).unwrap();
        let (job, replies) = recv_batch(&master_rx);
        assert_eq!(job.x.shape(), (2, 4));
        assert_eq!(replies.len(), 1);
        assert_eq!(job.x[(0, 0)], 9.0);
        for pad in 1..4 {
            assert_eq!(job.x[(0, pad)], 0.0, "pad column {pad} must be zero");
        }
    }

    #[test]
    fn batcher_flushes_at_effective_cap_below_configured_max() {
        // max_batch 5 but the widest artifact is 2: batches must flush
        // at 2, never exceeding what the backend can serve.
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 5,
                max_wait_ms: 10_000.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(1, Some(vec![1, 2]));
        for (i, v) in [1.0, 2.0, 3.0, 4.0].into_iter().enumerate() {
            let (r, _s) = mk_request(&entry, v, i as u64);
            req_tx.send(r).unwrap();
        }
        let (job1, replies1) = recv_batch(&master_rx);
        assert_eq!(job1.x.shape(), (1, 2));
        assert_eq!(replies1.len(), 2);
        let (job2, replies2) = recv_batch(&master_rx);
        assert_eq!(job2.x.shape(), (1, 2));
        assert_eq!(replies2.len(), 2);
        assert_eq!(job2.x[(0, 0)], 3.0, "order preserved across flushes");
    }

    #[test]
    fn requests_never_dropped_or_reordered() {
        // Property: across many requests, each gets exactly its own
        // column in submit order within a batch.
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 4,
                max_wait_ms: 50.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(1, None);
        let n = 25;
        let mut slots = Vec::new();
        for i in 0..n {
            let (r, s) = mk_request(&entry, i as f64, i as u64);
            req_tx.send(r).unwrap();
            slots.push(s);
        }
        let mut seen = 0;
        while seen < n {
            let (job, replies) = recv_batch(&master_rx);
            for route in &replies {
                let val = job.x[(0, route.column)];
                assert_eq!(val, seen as f64, "request order preserved");
                seen += 1;
            }
        }
        assert_eq!(seen, n);
    }

    #[test]
    fn models_batch_in_separate_lanes() {
        // Requests for different models never share a job, even when
        // interleaved within one batch window.
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 2,
                max_wait_ms: 10_000.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let e0 = mk_entry_id(0, 1);
        let e1 = mk_entry_id(1, 1);
        for (i, e) in [&e0, &e1, &e0, &e1].into_iter().enumerate() {
            let (r, _s) = mk_request(e, i as f64, i as u64);
            req_tx.send(r).unwrap();
        }
        let (job1, _) = recv_batch(&master_rx);
        let (job2, _) = recv_batch(&master_rx);
        // Both lanes flushed at cap 2, single-model each.
        assert_ne!(job1.model, job2.model);
        assert_eq!(job1.x.shape(), (1, 2));
        assert_eq!(job2.x.shape(), (1, 2));
    }

    #[test]
    fn higher_priority_dispatches_first_within_flush() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 2,
                max_wait_ms: 30.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(1, None);
        // r0 (prio 0) and r2 (prio 5) fill the first cap-2 flush: the
        // higher priority takes column 0 despite arriving second. r1
        // (prio -1) rides the next window alone.
        let (r0, _s0) = mk_request(&entry, 0.0, 0);
        let (mut r1, _s1) = mk_request(&entry, 1.0, 1);
        r1.priority = -1;
        let (mut r2, _s2) = mk_request(&entry, 2.0, 2);
        r2.priority = 5;
        req_tx.send(r0).unwrap();
        req_tx.send(r2).unwrap();
        req_tx.send(r1).unwrap();
        let (job1, replies1) = recv_batch(&master_rx);
        // First chunk: priorities 0 and 5 sorted → 2.0 (prio 5) first.
        assert_eq!(replies1.len(), 2);
        assert_eq!(job1.x[(0, 0)], 2.0, "high priority takes column 0");
        assert_eq!(job1.x[(0, 1)], 0.0);
        let (job2, replies2) = recv_batch(&master_rx);
        assert_eq!(replies2.len(), 1);
        assert_eq!(job2.x[(0, 0)], 1.0);
    }

    #[test]
    fn expired_requests_shed_with_deadline_error_and_counters_released() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let (_h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 8,
                max_wait_ms: 30.0,
            },
            Arc::clone(&metrics),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let entry = mk_entry(1, None);
        // Simulate the admission reservation the client side makes.
        assert!(entry.admission.try_reserve());
        assert!(entry.admission.try_reserve());
        metrics.queue_depth.fetch_add(2, Ordering::Relaxed);
        let (mut dead, dead_slot) = mk_request(&entry, 1.0, 0);
        dead.deadline = Instant::now() - Duration::from_millis(1);
        let (live, _live_slot) = mk_request(&entry, 2.0, 1);
        req_tx.send(dead).unwrap();
        req_tx.send(live).unwrap();
        let (job, replies) = recv_batch(&master_rx);
        // Only the live request dispatched.
        assert_eq!(replies.len(), 1);
        assert_eq!(job.x[(0, 0)], 2.0);
        // The shed one got its Deadline error and was accounted once.
        assert_eq!(dead_slot.wait(), Err(JobError::Deadline));
        assert_eq!(metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(entry.shed.load(Ordering::Relaxed), 1);
        // Both reservations released (shed + dispatched).
        assert_eq!(entry.admission.queued(), 0);
        assert_eq!(metrics.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn closing_the_channel_flushes_tails_and_sends_drain() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (h, _ctrl) = spawn(
            BatchConfig {
                max_batch: 100,
                max_wait_ms: 10_000.0, // window won't fire: drain must
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        let e0 = mk_entry_id(0, 1);
        let e1 = mk_entry_id(1, 1);
        let (r0, _s0) = mk_request(&e0, 1.0, 0);
        let (r1, _s1) = mk_request(&e1, 2.0, 1);
        req_tx.send(r0).unwrap();
        req_tx.send(r1).unwrap();
        drop(req_tx);
        h.join().unwrap();
        // Two tail batches (one per lane), then Drain, in that order.
        let mut batches = 0;
        let mut drained = false;
        while let Ok(msg) = master_rx.try_recv() {
            match msg {
                MasterMsg::Batch { .. } => {
                    assert!(!drained, "no batch may follow Drain");
                    batches += 1;
                }
                MasterMsg::Drain => drained = true,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(batches, 2);
        assert!(drained, "batcher must hand the master the drain baton");
    }

    #[test]
    fn pause_holds_dispatch_and_resume_releases_it() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, ctrl) = spawn(
            BatchConfig {
                max_batch: 1, // every request would flush instantly
                max_wait_ms: 1.0,
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        assert!(
            ctrl.pause(Duration::from_secs(5)),
            "pause must be acknowledged"
        );
        let entry = mk_entry(1, None);
        let (r, _s) = mk_request(&entry, 3.0, 0);
        req_tx.send(r).unwrap();
        // Paused: nothing may reach the master even past cap + window.
        assert!(
            master_rx.recv_timeout(Duration::from_millis(150)).is_err(),
            "a paused batcher must not dispatch"
        );
        ctrl.resume();
        let (job, replies) = recv_batch(&master_rx);
        assert_eq!(replies.len(), 1);
        assert_eq!(job.x[(0, 0)], 3.0, "buffered request flushes on resume");
    }

    #[test]
    fn set_batching_retunes_cap_live() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let (_h, ctrl) = spawn(
            BatchConfig {
                max_batch: 100,
                max_wait_ms: 10_000.0, // window never fires
            },
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        )
        .expect("spawn batcher");
        // Drop the cap to 2: the second request must flush the lane.
        ctrl.set_batching(2, 10_000.0);
        let entry = mk_entry(1, None);
        for (i, v) in [1.0, 2.0].into_iter().enumerate() {
            let (r, _s) = mk_request(&entry, v, i as u64);
            req_tx.send(r).unwrap();
        }
        let (job, replies) = recv_batch(&master_rx);
        assert_eq!(replies.len(), 2);
        assert_eq!(job.x.shape(), (1, 2));
    }
}
