//! Request batcher: folds client requests into batched coded jobs.
//!
//! Waits up to `max_wait_ms` for up to `max_batch` requests, stacks
//! their vectors into one `d × b` matrix `X`, pads `b` up to a batch
//! width the backend's artifact set supports (extra columns are zero and
//! sliced off at reply fan-out), and hands the job to the master. One
//! coded job then serves the whole batch — amortizing straggler waits,
//! decodes and PJRT dispatches across requests, and shaping worker
//! GEMMs for the MXU (DESIGN.md §Hardware-Adaptation).

use crate::config::schema::BatchConfig;
use crate::coordinator::messages::{
    JobBroadcast, JobId, JobRequest, MasterMsg, ReplyRoute,
};
use crate::coordinator::metrics::Metrics;
use crate::linalg::Matrix;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Spawn the batcher thread.
///
/// `supported_widths`: `None` = any width (native backend); `Some(ws)` =
/// pad to the smallest `w ∈ ws` with `w ≥ b` (PJRT artifact set).
pub fn spawn(
    d: usize,
    config: BatchConfig,
    supported_widths: Option<Vec<usize>>,
    metrics: Arc<Metrics>,
    rx: mpsc::Receiver<JobRequest>,
    master: mpsc::Sender<MasterMsg>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name("hiercode-batcher".to_string())
        .spawn(move || {
            let max_batch = effective_max_batch(config.max_batch, supported_widths.as_deref());
            let max_wait = Duration::from_secs_f64(config.max_wait_ms / 1e3);
            let mut next_id = 0u64;
            let mut pending: Vec<JobRequest> = Vec::new();
            let mut deadline: Option<Instant> = None;
            loop {
                // Wait for the first request (blocking) or until the
                // current batch's deadline.
                let msg = match deadline {
                    None => match rx.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break,
                    },
                    Some(dl) => {
                        let now = Instant::now();
                        if now >= dl {
                            None
                        } else {
                            match rx.recv_timeout(dl - now) {
                                Ok(m) => Some(m),
                                Err(mpsc::RecvTimeoutError::Timeout) => None,
                                Err(mpsc::RecvTimeoutError::Disconnected) => break,
                            }
                        }
                    }
                };
                match msg {
                    Some(req) => {
                        if req.x.len() != d {
                            let _ = req.reply.send(Err(format!(
                                "request dimension {} != cluster dimension {d}",
                                req.x.len()
                            )));
                            continue;
                        }
                        Metrics::inc(&metrics.requests);
                        pending.push(req);
                        if pending.len() == 1 {
                            deadline = Some(Instant::now() + max_wait);
                        }
                        if pending.len() >= max_batch {
                            flush(
                                &mut pending,
                                &mut next_id,
                                d,
                                supported_widths.as_deref(),
                                &master,
                            );
                            deadline = None;
                        }
                    }
                    None => {
                        // Deadline hit.
                        if !pending.is_empty() {
                            flush(
                                &mut pending,
                                &mut next_id,
                                d,
                                supported_widths.as_deref(),
                                &master,
                            );
                        }
                        deadline = None;
                    }
                }
            }
            // Channel closed: flush the tail.
            if !pending.is_empty() {
                flush(
                    &mut pending,
                    &mut next_id,
                    d,
                    supported_widths.as_deref(),
                    &master,
                );
            }
        })
        .expect("failed to spawn batcher thread")
}

/// Cap the configured batch size at the largest width the artifact set
/// can serve.
pub fn effective_max_batch(configured: usize, supported: Option<&[usize]>) -> usize {
    match supported {
        None => configured,
        Some(ws) => {
            let max_w = ws.iter().copied().max().unwrap_or(1);
            configured.min(max_w).max(1)
        }
    }
}

fn flush(
    pending: &mut Vec<JobRequest>,
    next_id: &mut u64,
    d: usize,
    supported: Option<&[usize]>,
    master: &mpsc::Sender<MasterMsg>,
) {
    let b = pending.len();
    let width = match crate::coordinator::backend::pick_batch_width(supported, b) {
        Ok(w) => w,
        Err(e) => {
            for req in pending.drain(..) {
                let _ = req.reply.send(Err(format!("{e}")));
            }
            return;
        }
    };
    // Stack request vectors into X (d × width), zero-padded.
    let mut x = Matrix::zeros(d, width);
    let mut replies = Vec::with_capacity(b);
    for (col, req) in pending.drain(..).enumerate() {
        for (row, &v) in req.x.iter().enumerate() {
            x[(row, col)] = v;
        }
        replies.push(ReplyRoute {
            reply: req.reply,
            column: col,
            submitted_at: req.submitted_at,
            req_id: req.req_id,
        });
    }
    let id = JobId(*next_id);
    *next_id += 1;
    let _ = master.send(MasterMsg::Batch {
        job: JobBroadcast {
            id,
            x: Arc::new(x),
        },
        replies,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_request(d: usize, v: f64) -> (JobRequest, mpsc::Receiver<Result<Vec<f64>, String>>) {
        let (tx, rx) = mpsc::channel();
        (
            JobRequest {
                x: vec![v; d],
                reply: tx,
                submitted_at: Instant::now(),
                req_id: crate::coordinator::messages::RequestId(v.to_bits()),
            },
            rx,
        )
    }

    fn recv_batch(master_rx: &mpsc::Receiver<MasterMsg>) -> (JobBroadcast, Vec<ReplyRoute>) {
        match master_rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            MasterMsg::Batch { job, replies } => (job, replies),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let _h = spawn(
            3,
            BatchConfig {
                max_batch: 2,
                max_wait_ms: 10_000.0, // deadline never fires in this test
            },
            None,
            metrics,
            req_rx,
            master_tx,
        );
        let (r1, _rx1) = mk_request(3, 1.0);
        let (r2, _rx2) = mk_request(3, 2.0);
        req_tx.send(r1).unwrap();
        req_tx.send(r2).unwrap();
        let (job, replies) = recv_batch(&master_rx);
        assert_eq!(job.x.shape(), (3, 2));
        assert_eq!(job.x[(0, 0)], 1.0);
        assert_eq!(job.x[(0, 1)], 2.0);
        assert_eq!(replies.len(), 2);
        assert_eq!(replies[1].column, 1);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let _h = spawn(
            2,
            BatchConfig {
                max_batch: 100,
                max_wait_ms: 20.0,
            },
            None,
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        );
        let (r1, _rx1) = mk_request(2, 5.0);
        req_tx.send(r1).unwrap();
        let t0 = Instant::now();
        let (job, replies) = recv_batch(&master_rx);
        assert!(t0.elapsed() >= Duration::from_millis(10));
        assert_eq!(job.x.shape(), (2, 1));
        assert_eq!(replies.len(), 1);
    }

    #[test]
    fn pads_to_supported_width() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let _h = spawn(
            2,
            BatchConfig {
                max_batch: 3,
                max_wait_ms: 20.0,
            },
            Some(vec![1, 4, 8]),
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        );
        for v in [1.0, 2.0, 3.0] {
            let (r, _rx) = mk_request(2, v);
            req_tx.send(r).unwrap();
        }
        let (job, replies) = recv_batch(&master_rx);
        // 3 requests padded to width 4.
        assert_eq!(job.x.shape(), (2, 4));
        assert_eq!(job.x[(0, 3)], 0.0, "pad column must be zero");
        assert_eq!(replies.len(), 3);
    }

    #[test]
    fn wrong_dimension_rejected_immediately() {
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, _master_rx) = mpsc::channel();
        let _h = spawn(
            4,
            BatchConfig::default(),
            None,
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        );
        let (r, rx) = mk_request(3, 1.0); // wrong d
        req_tx.send(r).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.is_err());
    }

    #[test]
    fn effective_max_batch_caps_at_artifact_width() {
        assert_eq!(effective_max_batch(16, Some(&[1, 4, 8])), 8);
        assert_eq!(effective_max_batch(4, Some(&[1, 4, 8])), 4);
        assert_eq!(effective_max_batch(16, None), 16);
        // Exact cap match.
        assert_eq!(effective_max_batch(8, Some(&[1, 4, 8])), 8);
        // Degenerate width set still yields a usable batch of 1.
        assert_eq!(effective_max_batch(16, Some(&[])), 1);
        // A width set whose max is below every batch still clamps to it.
        assert_eq!(effective_max_batch(100, Some(&[2])), 2);
    }

    #[test]
    fn pad_width_selection_edge_cases() {
        use crate::coordinator::backend::pick_batch_width;
        // Exact match: no padding.
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 4).unwrap(), 4);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 1).unwrap(), 1);
        // Smallest larger supported width.
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 2).unwrap(), 4);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 3).unwrap(), 4);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 5).unwrap(), 8);
        // No supported width >= b: a Runtime error naming the problem.
        assert!(pick_batch_width(Some(&[1, 4, 8]), 9).is_err());
        assert!(pick_batch_width(Some(&[]), 1).is_err());
        // Native backend serves any width verbatim.
        assert_eq!(pick_batch_width(None, 17).unwrap(), 17);
    }

    #[test]
    fn batcher_pads_single_request_to_smallest_supported_width() {
        // Artifact set without width 1: a lone request rides a width-4
        // job whose pad columns are zero.
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let _h = spawn(
            2,
            BatchConfig {
                max_batch: 4,
                max_wait_ms: 10.0,
            },
            Some(vec![4, 8]),
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        );
        let (r, _rx) = mk_request(2, 9.0);
        req_tx.send(r).unwrap();
        let (job, replies) = recv_batch(&master_rx);
        assert_eq!(job.x.shape(), (2, 4));
        assert_eq!(replies.len(), 1);
        assert_eq!(job.x[(0, 0)], 9.0);
        for pad in 1..4 {
            assert_eq!(job.x[(0, pad)], 0.0, "pad column {pad} must be zero");
        }
    }

    #[test]
    fn batcher_flushes_at_effective_cap_below_configured_max() {
        // max_batch 5 but the widest artifact is 2: batches must flush
        // at 2, never exceeding what the backend can serve.
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let _h = spawn(
            1,
            BatchConfig {
                max_batch: 5,
                max_wait_ms: 10_000.0,
            },
            Some(vec![1, 2]),
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        );
        for v in [1.0, 2.0, 3.0, 4.0] {
            let (r, _rx) = mk_request(1, v);
            req_tx.send(r).unwrap();
        }
        let (job1, replies1) = recv_batch(&master_rx);
        assert_eq!(job1.x.shape(), (1, 2));
        assert_eq!(replies1.len(), 2);
        let (job2, replies2) = recv_batch(&master_rx);
        assert_eq!(job2.x.shape(), (1, 2));
        assert_eq!(replies2.len(), 2);
        assert_eq!(job2.x[(0, 0)], 3.0, "order preserved across flushes");
    }

    #[test]
    fn requests_never_dropped_or_reordered() {
        // Property: across many requests, each gets exactly its own
        // column in submit order within a batch.
        let (req_tx, req_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let _h = spawn(
            1,
            BatchConfig {
                max_batch: 4,
                max_wait_ms: 50.0,
            },
            None,
            Arc::new(Metrics::new()),
            req_rx,
            master_tx,
        );
        let n = 25;
        let mut rxs = Vec::new();
        for i in 0..n {
            let (r, rx) = mk_request(1, i as f64);
            req_tx.send(r).unwrap();
            rxs.push(rx);
        }
        let mut seen = 0;
        while seen < n {
            let (job, replies) = recv_batch(&master_rx);
            for route in &replies {
                let val = job.x[(0, route.column)];
                assert_eq!(val, seen as f64, "request order preserved");
                seen += 1;
            }
        }
        assert_eq!(seen, n);
    }
}
