//! Protocol types flowing between coordinator threads, plus the shared
//! per-model admission state ([`ModelEntry`]) and the client-facing
//! completion surface ([`CompletionSlot`]).

use crate::coordinator::backend::WorkerShard;
use crate::linalg::Matrix;
use crate::sync::{AdmissionGate, Condvar, Mutex, RwLock};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Identifies one batched coded job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

/// Identifies one client request (a single column of some batched job).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Identifies one registered model (a named computation `A·x`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(pub u32);

/// How a served request fails, as delivered through its completion
/// slot. `crate::Error` is not `Clone`, so the coordinator speaks this
/// smaller vocabulary and the handle translates at the API boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The admission deadline passed while the request was queued.
    Deadline,
    /// Decode or protocol failure.
    Failed(String),
    /// The cluster shut down before the request completed.
    Shutdown,
    /// The failure detector found fewer healthy groups than the outer
    /// threshold `k2`: the job can never decode, so it fails fast
    /// instead of hanging until its deadline.
    Insufficient {
        /// Healthy groups required (`k2`).
        needed: usize,
        /// Healthy groups remaining.
        got: usize,
    },
}

impl From<JobError> for crate::Error {
    fn from(e: JobError) -> Self {
        match e {
            JobError::Deadline => crate::Error::DeadlineExceeded,
            JobError::Failed(m) => crate::Error::Coordinator(m),
            JobError::Shutdown => {
                crate::Error::Coordinator("cluster shut down before replying".into())
            }
            JobError::Insufficient { needed, got } => {
                crate::Error::Insufficient { needed, got }
            }
        }
    }
}

/// The terminal outcome of one request.
pub type JobResult = std::result::Result<Vec<f64>, JobError>;

#[derive(Debug)]
enum SlotState {
    /// No result yet.
    Pending,
    /// Result delivered, not yet taken by the client.
    Done(JobResult),
    /// Result taken; later waits fail rather than block.
    Taken,
}

/// A one-shot completion slot: the coordinator side calls
/// [`CompletionSlot::complete`] exactly once per terminal outcome
/// (first write wins, later writes are ignored), the client side polls
/// or blocks on the other end. Unlike an `mpsc` pair this is `Sync`, so
/// a [`crate::coordinator::JobHandle`] is `Send` and pollable from any
/// thread.
///
/// Built on the [`crate::sync`] facade: the mutex+condvar pair is
/// poison-transparent (a panicking completer must not cascade into
/// every waiter), and under `--features modelcheck` the first-write-
/// wins and no-lost-wakeup invariants are checked exhaustively over
/// all interleavings in `tests/model_check.rs`.
#[derive(Debug)]
pub struct CompletionSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl Default for CompletionSlot {
    fn default() -> Self {
        Self::new()
    }
}

impl CompletionSlot {
    /// Fresh, pending slot.
    pub fn new() -> Self {
        Self {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
        }
    }

    /// Deliver the terminal outcome. The first write wins; any later
    /// write is ignored (e.g. a deadline shed racing a completion).
    /// Returns `true` iff this call was the winning (first) write —
    /// callers key their terminal accounting (shed / completed
    /// counters) on it, which makes shedding **idempotent per
    /// request**: a request shed once can never be counted shed again
    /// downstream.
    pub fn complete(&self, result: JobResult) -> bool {
        let mut s = self.state.lock();
        if matches!(*s, SlotState::Pending) {
            *s = SlotState::Done(result);
            self.cv.notify_all();
            true
        } else {
            false
        }
    }

    /// Non-blocking poll: `Some` exactly once, when the outcome is in;
    /// `None` while pending (and after the outcome was already taken).
    pub fn try_take(&self) -> Option<JobResult> {
        let mut s = self.state.lock();
        match std::mem::replace(&mut *s, SlotState::Taken) {
            SlotState::Done(r) => Some(r),
            prev => {
                *s = prev;
                None
            }
        }
    }

    /// Block until the outcome is in and take it.
    pub fn wait(&self) -> JobResult {
        let mut s = self.state.lock();
        loop {
            match std::mem::replace(&mut *s, SlotState::Taken) {
                SlotState::Done(r) => return r,
                SlotState::Taken => {
                    return Err(JobError::Failed("result already consumed".into()))
                }
                SlotState::Pending => {
                    *s = SlotState::Pending;
                    s = self.cv.wait(s);
                }
            }
        }
    }

    /// Block up to `timeout`; `None` on timeout (outcome left in place).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<JobResult> {
        let deadline = Instant::now() + timeout;
        let mut s = self.state.lock();
        loop {
            match std::mem::replace(&mut *s, SlotState::Taken) {
                SlotState::Done(r) => return Some(r),
                SlotState::Taken => {
                    return Some(Err(JobError::Failed("result already consumed".into())))
                }
                SlotState::Pending => {
                    *s = SlotState::Pending;
                    let now = Instant::now();
                    if now >= deadline {
                        return None;
                    }
                    let (guard, _) = self.cv.wait_timeout(s, deadline - now);
                    s = guard;
                }
            }
        }
    }
}

/// One registered model: immutable routing facts plus the shared
/// admission-control state. Clients reserve a queue slot through
/// [`AdmissionGate::try_reserve`] at submit time; the batcher releases
/// slots as it dispatches or sheds.
#[derive(Debug)]
pub struct ModelEntry {
    /// Model identity (worker shard-table key).
    pub id: ModelId,
    /// Registered name.
    pub name: String,
    /// Input dimension (columns of the model's matrix).
    pub d: usize,
    /// Output dimension (rows of the model's matrix).
    pub m: usize,
    /// Bounded admission queue: reservations beyond the cap bounce
    /// with [`crate::Error::Busy`].
    pub admission: AdmissionGate,
    /// Batch widths the backend can serve for this model's shard shape
    /// (`None` = unrestricted native backend).
    pub supported_widths: Option<Vec<usize>>,
    /// Requests accepted for this model.
    pub accepted: AtomicU64,
    /// Submissions bounced with `Busy`.
    pub rejected: AtomicU64,
    /// Requests shed because their deadline expired while queued.
    pub shed: AtomicU64,
    /// Requests answered successfully.
    pub completed: AtomicU64,
}

impl ModelEntry {
    /// Fresh entry with zeroed counters.
    pub fn new(
        id: ModelId,
        name: &str,
        d: usize,
        m: usize,
        cap: usize,
        supported_widths: Option<Vec<usize>>,
    ) -> Self {
        Self {
            id,
            name: name.to_string(),
            d,
            m,
            admission: AdmissionGate::new(cap),
            supported_widths,
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            completed: AtomicU64::new(0),
        }
    }
}

/// A client request: multiply `entry`'s matrix by `x`.
#[derive(Debug)]
pub struct JobRequest {
    /// The model this request targets.
    pub entry: Arc<ModelEntry>,
    /// The request vector (`entry.d` elements).
    pub x: Vec<f64>,
    /// Where the terminal outcome is delivered.
    pub slot: Arc<CompletionSlot>,
    /// Client-side submit timestamp (for end-to-end latency metrics).
    pub submitted_at: Instant,
    /// Admission deadline: if still undispatched past this instant the
    /// request is shed with [`JobError::Deadline`].
    pub deadline: Instant,
    /// Batching priority: higher dispatches first within a flush.
    pub priority: i32,
    /// Cluster-unique request identity (used for cancellation).
    pub req_id: RequestId,
}

/// A batched job broadcast from master to submasters.
#[derive(Clone, Debug)]
pub struct JobBroadcast {
    /// Job id.
    pub id: JobId,
    /// Which model's shards this job multiplies.
    pub model: ModelId,
    /// Output rows `m` of that model (sizes the decode sessions).
    pub out_rows: usize,
    /// The batched request matrix, `d × b` (shared, read-only).
    pub x: Arc<Matrix>,
}

/// Worker → submaster: one completed (sub-)task's product. In the
/// all-or-nothing model a worker sends exactly one of these per job
/// (`subtask = 0`, `data` the whole shard product); in partial-work
/// mode it streams one per completed sub-task, so a group can harvest
/// stragglers' partial work.
#[derive(Debug)]
pub struct WorkerDone {
    /// Job id.
    pub id: JobId,
    /// In-group worker index `j`.
    pub index: usize,
    /// Sub-task index `s ∈ [0, r)` within worker `j`'s shard (0 when
    /// the group runs all-or-nothing tasks).
    pub subtask: usize,
    /// The (sub-)shard product (`rows × b`).
    pub data: Matrix,
}

/// Submaster → master: one partial result feeding the master's decode
/// session. For schemes with group decoding (hierarchical) `shard` is
/// the **group index** and `data` the decoded `Ã_i · X`; for relay
/// groups `shard` is the **flat worker index** and `data` the raw shard
/// product.
#[derive(Debug)]
pub struct PartialResult {
    /// Job id.
    pub id: JobId,
    /// Shard index in the master session's index space (see above).
    pub shard: usize,
    /// The partial product.
    pub data: Matrix,
    /// Whether this partial is a group-decoded result (as opposed to a
    /// relayed raw worker product). Carried explicitly — a trivial
    /// systematic decode can cost 0 flops, so `decode_flops > 0` is not
    /// a reliable proxy — so the socket hub can mirror the submaster's
    /// decode accounting exactly.
    pub decoded: bool,
    /// Flops the submaster spent decoding (0 for relayed products).
    pub decode_flops: u64,
    /// When the partial was produced (`S_i`, before link delay).
    pub finished_at: Instant,
}

/// A worker's command channel behind a reader/writer lock: senders
/// (submaster broadcasts, model registration) go through `read()`;
/// a chaos restart swaps in the respawned worker's fresh channel under
/// `write()`, which also mutually excludes the shard re-ship against
/// concurrent sends — `Load`-before-`Compute` FIFO holds on the new
/// channel too.
pub type WorkerLink = Arc<RwLock<std::sync::mpsc::Sender<WorkerCmd>>>;

/// Commands to a worker thread.
#[derive(Debug)]
pub enum WorkerCmd {
    /// Install a model's shard. Registration sends `Load` on the same
    /// channel later `Compute`s arrive on, so FIFO ordering guarantees
    /// the shard is in place before any job needs it.
    Load {
        /// The model the shard belongs to.
        model: ModelId,
        /// This worker's coded shard of the model.
        shard: Box<WorkerShard>,
    },
    /// Compute this job's shard product.
    Compute(JobBroadcast),
    /// Exit the thread.
    Shutdown,
}

/// A replacement coding scheme carried by hot-reload messages. The
/// newtype exists because `Arc<dyn CodedScheme>` is neither `Debug`
/// nor derivable-`Clone` inside the message enums, so both are
/// implemented by hand (Debug prints the scheme's name).
pub struct SchemeSwap(pub Arc<dyn crate::coding::CodedScheme>);

impl Clone for SchemeSwap {
    fn clone(&self) -> Self {
        SchemeSwap(Arc::clone(&self.0))
    }
}

impl std::fmt::Debug for SchemeSwap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SchemeSwap({})", self.0.name())
    }
}

/// Everything a submaster thread receives (single-queue actor).
#[derive(Debug)]
pub enum SubmasterMsg {
    /// New job from the master.
    Job(JobBroadcast),
    /// A worker finished.
    Done(WorkerDone),
    /// The master finished (or cancelled) this job: stop feeding it,
    /// cancel still-pending worker computes.
    Finish(JobId),
    /// Liveness beacon from worker `index` (sent on its heartbeat
    /// cadence; the submaster forwards it upstream while the group's
    /// uplink is alive).
    Heartbeat(usize),
    /// Hot reload: decode subsequent jobs under this scheme. Sent only
    /// while the cluster is quiesced (no jobs in flight), so no decode
    /// session ever mixes encodings.
    Swap(SchemeSwap),
    /// Exit.
    Shutdown,
}

/// Everything the master thread receives.
#[derive(Debug)]
pub enum MasterMsg {
    /// A batched job from the batcher, with the requests that compose
    /// it (one [`ReplyRoute`] per column of `X`).
    Batch {
        /// The job.
        job: JobBroadcast,
        /// Reply routing: one entry per column of `X`.
        replies: Vec<ReplyRoute>,
    },
    /// A partial result arrived.
    Partial(PartialResult),
    /// A client abandoned its request (e.g. `wait_timeout` elapsed):
    /// drop its reply route; cancel the whole job once no client is
    /// left waiting on it.
    CancelRequest(RequestId),
    /// The batcher flushed its last request and exited (sent on its own
    /// channel clone, so every `Batch` precedes it). The master drains
    /// in-flight jobs — bounded by the drain grace — completing or
    /// failing every route, then shuts the worker tree down.
    Drain,
    /// Liveness beacon: `worker: Some(j)` relays worker `j`'s
    /// heartbeat, `None` is the submaster's own. A severed uplink
    /// silences a group's entire beacon stream — exactly the signal
    /// the failure detector uses to mark the whole group dead.
    Heartbeat {
        /// Reporting group.
        group: usize,
        /// In-group worker index, or `None` for the submaster itself.
        worker: Option<usize>,
    },
    /// Hot reload: replace the master's decode scheme (and the derived
    /// topology/thresholds). Sent only while quiesced, between jobs.
    Reconfigure(SchemeSwap),
    /// Hot reload: answer on the enclosed channel once no job is in
    /// flight. The batcher is paused first, so once the drain set is
    /// empty it stays empty until the rollout resumes it.
    Quiesce(std::sync::mpsc::Sender<()>),
}

/// Group-local cancellation registry (§Perf): the submaster marks a job
/// the moment its `k1`-th product arrives; workers still sleeping or
/// queued for that job skip the compute entirely. The paper's scheme
/// only ever *discards* straggler results — cancelling the unneeded
/// work is pure savings (on a shared-core testbed it directly shortens
/// the critical path).
#[derive(Debug, Default)]
pub struct CancelSet {
    inner: RwLock<std::collections::HashSet<JobId>>,
}

impl CancelSet {
    /// Fresh, empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark `id` as no-longer-needed.
    pub fn mark(&self, id: JobId) {
        let mut set = self.inner.write();
        // Unbounded growth guard: stale entries only cost a wasted
        // compute if dropped, never correctness.
        if set.len() > 4096 {
            set.clear();
        }
        set.insert(id);
    }

    /// True if `id` has been marked.
    pub fn is_cancelled(&self, id: JobId) -> bool {
        self.inner.read().contains(&id)
    }
}

/// Where one column of a batched result goes.
#[derive(Debug)]
pub struct ReplyRoute {
    /// The model the request targeted (per-model accounting).
    pub entry: Arc<ModelEntry>,
    /// The client's completion slot.
    pub slot: Arc<CompletionSlot>,
    /// Which column of the batched result belongs to this client.
    pub column: usize,
    /// Client submit time.
    pub submitted_at: Instant,
    /// Admission deadline (the master sheds expired routes at batch
    /// receipt — queueing in the master's channel counts too).
    pub deadline: Instant,
    /// The request this column answers (for cancellation).
    pub req_id: RequestId,
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn completion_slot_first_write_wins_and_take_is_single_shot() {
        let slot = CompletionSlot::new();
        assert!(slot.try_take().is_none());
        assert!(slot.complete(Ok(vec![1.0, 2.0])), "first write wins");
        assert!(
            !slot.complete(Err(JobError::Deadline)),
            "second write reports it lost (idempotent-shed keying)"
        );
        assert_eq!(slot.try_take(), Some(Ok(vec![1.0, 2.0])));
        // Taken: later polls see nothing, later waits fail fast.
        assert!(slot.try_take().is_none());
        assert!(slot.wait().is_err());
    }

    #[test]
    fn completion_slot_blocks_until_completed() {
        let slot = Arc::new(CompletionSlot::new());
        let s2 = Arc::clone(&slot);
        let h = std::thread::spawn(move || s2.wait());
        std::thread::sleep(Duration::from_millis(20));
        slot.complete(Ok(vec![7.0]));
        assert_eq!(h.join().unwrap(), Ok(vec![7.0]));
    }

    #[test]
    fn completion_slot_wait_timeout_leaves_pending_intact() {
        let slot = CompletionSlot::new();
        assert!(slot.wait_timeout(Duration::from_millis(10)).is_none());
        // A timeout must not consume the slot.
        slot.complete(Err(JobError::Shutdown));
        assert_eq!(
            slot.wait_timeout(Duration::from_millis(10)),
            Some(Err(JobError::Shutdown))
        );
    }

    #[test]
    fn job_error_maps_to_crate_errors() {
        assert!(matches!(
            crate::Error::from(JobError::Deadline),
            crate::Error::DeadlineExceeded
        ));
        assert!(matches!(
            crate::Error::from(JobError::Failed("x".into())),
            crate::Error::Coordinator(_)
        ));
        assert!(matches!(
            crate::Error::from(JobError::Shutdown),
            crate::Error::Coordinator(_)
        ));
        assert!(matches!(
            crate::Error::from(JobError::Insufficient { needed: 2, got: 1 }),
            crate::Error::Insufficient { needed: 2, got: 1 }
        ));
    }
}
