//! Protocol types flowing between coordinator threads.

use crate::linalg::Matrix;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Identifies one batched coded job.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

/// Identifies one client request (a single column of some batched job).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// A client request: multiply the cluster's matrix `A` by `x`.
#[derive(Debug)]
pub struct JobRequest {
    /// The request vector (`d` elements).
    pub x: Vec<f64>,
    /// Where to deliver the result (`m` elements) or an error message.
    pub reply: mpsc::Sender<Result<Vec<f64>, String>>,
    /// Client-side submit timestamp (for end-to-end latency metrics).
    pub submitted_at: Instant,
    /// Cluster-unique request identity (used for cancellation).
    pub req_id: RequestId,
}

/// A batched job broadcast from master to submasters.
#[derive(Clone, Debug)]
pub struct JobBroadcast {
    /// Job id.
    pub id: JobId,
    /// The batched request matrix, `d × b` (shared, read-only).
    pub x: Arc<Matrix>,
}

/// Worker → submaster: one shard product.
#[derive(Debug)]
pub struct WorkerDone {
    /// Job id.
    pub id: JobId,
    /// In-group worker index `j`.
    pub index: usize,
    /// The product `Â_{i,j} · X` (`r × b`).
    pub data: Matrix,
}

/// Submaster → master: one partial result feeding the master's decode
/// session. For schemes with group decoding (hierarchical) `shard` is
/// the **group index** and `data` the decoded `Ã_i · X`; for relay
/// groups `shard` is the **flat worker index** and `data` the raw shard
/// product.
#[derive(Debug)]
pub struct PartialResult {
    /// Job id.
    pub id: JobId,
    /// Shard index in the master session's index space (see above).
    pub shard: usize,
    /// The partial product.
    pub data: Matrix,
    /// Flops the submaster spent decoding (0 for relayed products).
    pub decode_flops: u64,
    /// When the partial was produced (`S_i`, before link delay).
    pub finished_at: Instant,
}

/// Commands to a worker thread.
#[derive(Debug)]
pub enum WorkerCmd {
    /// Compute this job's shard product.
    Compute(JobBroadcast),
    /// Exit the thread.
    Shutdown,
}

/// Everything a submaster thread receives (single-queue actor).
#[derive(Debug)]
pub enum SubmasterMsg {
    /// New job from the master.
    Job(JobBroadcast),
    /// A worker finished.
    Done(WorkerDone),
    /// The master finished (or cancelled) this job: stop feeding it,
    /// cancel still-pending worker computes.
    Finish(JobId),
    /// Exit.
    Shutdown,
}

/// Everything the master thread receives.
#[derive(Debug)]
pub enum MasterMsg {
    /// A batched job from the batcher, with the requests that compose
    /// it: `(reply channel, column, submit time)` per request.
    Batch {
        /// The job.
        job: JobBroadcast,
        /// Reply routing: one entry per column of `X`.
        replies: Vec<ReplyRoute>,
    },
    /// A partial result arrived.
    Partial(PartialResult),
    /// A client abandoned its request (e.g. `wait_timeout` elapsed):
    /// drop its reply route; cancel the whole job once no client is
    /// left waiting on it.
    CancelRequest(RequestId),
    /// Exit.
    Shutdown,
}

/// Group-local cancellation registry (§Perf): the submaster marks a job
/// the moment its `k1`-th product arrives; workers still sleeping or
/// queued for that job skip the compute entirely. The paper's scheme
/// only ever *discards* straggler results — cancelling the unneeded
/// work is pure savings (on a shared-core testbed it directly shortens
/// the critical path).
#[derive(Debug, Default)]
pub struct CancelSet {
    inner: std::sync::RwLock<std::collections::HashSet<JobId>>,
}

impl CancelSet {
    /// Fresh, empty set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark `id` as no-longer-needed.
    pub fn mark(&self, id: JobId) {
        let mut set = self.inner.write().expect("cancel set poisoned");
        // Unbounded growth guard: stale entries only cost a wasted
        // compute if dropped, never correctness.
        if set.len() > 4096 {
            set.clear();
        }
        set.insert(id);
    }

    /// True if `id` has been marked.
    pub fn is_cancelled(&self, id: JobId) -> bool {
        self.inner.read().expect("cancel set poisoned").contains(&id)
    }
}

/// Where one column of a batched result goes.
#[derive(Debug)]
pub struct ReplyRoute {
    /// The client's reply channel.
    pub reply: mpsc::Sender<Result<Vec<f64>, String>>,
    /// Which column of the batched result belongs to this client.
    pub column: usize,
    /// Client submit time.
    pub submitted_at: Instant,
    /// The request this column answers (for cancellation).
    pub req_id: RequestId,
}
