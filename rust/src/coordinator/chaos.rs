//! Chaos driver and failure detector: the dynamic-fault side of the
//! coordinator.
//!
//! Three pieces:
//!
//! * [`LivenessConfig`] — heartbeat cadence and the detector's
//!   suspect/dead timeouts (parsed from the config's `chaos` section).
//! * [`FailureDetector`] — the master's timeout-based liveness state
//!   machine. Pure function of `(heartbeats, now_ms)` against a
//!   [`Clock`](crate::sync::Clock): every worker and group is `Alive`
//!   until its beacons go quiet for `suspect_ms` (→ [`Liveness::
//!   Suspected`]) and then `dead_ms` (→ [`Liveness::Dead`]); one fresh
//!   beacon revives it. Indexed by `Vec`, clocked externally — unit
//!   tests drive it with a [`MockClock`](crate::sync::MockClock) and
//!   never sleep.
//! * [`spawn`] — the chaos driver thread: executes a seeded
//!   [`FaultPlan`] against a live cluster through the [`FaultInjector`]
//!   surface, tallying a [`ChaosReport`]. The plan is a pure function
//!   of its seed, so two same-seed runs inject identical event
//!   sequences — the `hiercode chaos` harness's determinism verdict.

use crate::coordinator::fault::{FaultAction, FaultPlan};
use crate::sync::Clock;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Liveness settings for the coordinator tree.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LivenessConfig {
    /// Master switch: when off, no heartbeats are sent and the master
    /// never sweeps (the pre-liveness quiet-channel behavior).
    pub enabled: bool,
    /// Heartbeat cadence for workers and submasters.
    pub heartbeat: Duration,
    /// Beacon silence after which a worker/group is `Suspected`.
    pub suspect: Duration,
    /// Beacon silence after which a worker/group is `Dead`.
    pub dead: Duration,
}

impl LivenessConfig {
    /// Liveness on, with the given cadence and timeouts.
    pub fn new(heartbeat: Duration, suspect: Duration, dead: Duration) -> Self {
        Self {
            enabled: true,
            heartbeat,
            suspect,
            dead,
        }
    }

    /// Liveness off: no beacons, no sweeps, channels stay quiet.
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            heartbeat: Duration::from_millis(25),
            suspect: Duration::from_millis(1000),
            dead: Duration::from_millis(5000),
        }
    }

    /// The worker/submaster heartbeat parameter: `Some(cadence)` when
    /// enabled.
    pub fn beat_period(&self) -> Option<Duration> {
        self.enabled.then_some(self.heartbeat)
    }
}

/// Detector verdict for one worker or group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Liveness {
    /// Beacons within `suspect_ms`.
    Alive,
    /// Quiet past `suspect_ms` but not yet `dead_ms`.
    Suspected,
    /// Quiet past `dead_ms`: treated as failed for degradation math.
    Dead,
}

/// Timeout-based failure detector over the coordinator's beacon
/// streams. `Vec`-indexed (no hash iteration) and externally clocked:
/// deterministic given the same beat/now sequence.
#[derive(Debug)]
pub struct FailureDetector {
    suspect_ms: u64,
    dead_ms: u64,
    /// Last beacon per worker, `[group][index]`, ms.
    workers: Vec<Vec<u64>>,
    /// Last beacon per group (worker-relayed or submaster-own), ms.
    groups: Vec<u64>,
}

impl FailureDetector {
    /// Fresh detector: everything counts as having beaconed at
    /// `now_ms`, so nothing is falsely suspected at startup.
    pub fn new(group_sizes: &[usize], suspect_ms: u64, dead_ms: u64, now_ms: u64) -> Self {
        Self {
            suspect_ms,
            dead_ms: dead_ms.max(suspect_ms),
            workers: group_sizes.iter().map(|&n| vec![now_ms; n]).collect(),
            groups: vec![now_ms; group_sizes.len()],
        }
    }

    /// Record a beacon: `worker: Some(j)` is worker `j`'s (relayed by
    /// its submaster), `None` the submaster's own. Either proves the
    /// group's uplink works, so both refresh the group timestamp.
    pub fn beat(&mut self, group: usize, worker: Option<usize>, now_ms: u64) {
        if let Some(g) = self.groups.get_mut(group) {
            *g = now_ms.max(*g);
        }
        if let Some(j) = worker {
            if let Some(w) = self.workers.get_mut(group).and_then(|g| g.get_mut(j)) {
                *w = now_ms.max(*w);
            }
        }
    }

    fn classify(&self, last_ms: u64, now_ms: u64) -> Liveness {
        let quiet = now_ms.saturating_sub(last_ms);
        if quiet >= self.dead_ms {
            Liveness::Dead
        } else if quiet >= self.suspect_ms {
            Liveness::Suspected
        } else {
            Liveness::Alive
        }
    }

    /// Verdict for worker `(group, j)`. Out-of-range ⇒ `Dead` (a
    /// worker the detector never knew cannot be alive).
    pub fn worker_state(&self, group: usize, j: usize, now_ms: u64) -> Liveness {
        self.workers
            .get(group)
            .and_then(|g| g.get(j))
            .map(|&last| self.classify(last, now_ms))
            .unwrap_or(Liveness::Dead)
    }

    /// Verdict for a group's beacon stream (its uplink + submaster).
    pub fn group_state(&self, group: usize, now_ms: u64) -> Liveness {
        self.groups
            .get(group)
            .map(|&last| self.classify(last, now_ms))
            .unwrap_or(Liveness::Dead)
    }

    /// Workers of `group` not currently `Dead`.
    pub fn alive_workers(&self, group: usize, now_ms: u64) -> usize {
        self.workers
            .get(group)
            .map(|g| {
                g.iter()
                    .filter(|&&last| self.classify(last, now_ms) != Liveness::Dead)
                    .count()
            })
            .unwrap_or(0)
    }

    /// Workers of `group` currently `Suspected` (quiet, not yet dead).
    pub fn suspected_workers(&self, group: usize, now_ms: u64) -> usize {
        self.workers
            .get(group)
            .map(|g| {
                g.iter()
                    .filter(|&&last| self.classify(last, now_ms) == Liveness::Suspected)
                    .count()
            })
            .unwrap_or(0)
    }

    /// Groups that can still deliver a partial: beacon stream not
    /// `Dead` and at least `thresholds[g]` (= `k1_g`) workers not
    /// `Dead` — with `r` sub-tasks per worker that is exactly
    /// "≥ k1·r reachable sub-results".
    pub fn healthy_groups(&self, thresholds: &[usize], now_ms: u64) -> usize {
        self.groups
            .iter()
            .enumerate()
            .filter(|&(g, _)| {
                self.group_state(g, now_ms) != Liveness::Dead
                    && self.alive_workers(g, now_ms)
                        >= thresholds.get(g).copied().unwrap_or(usize::MAX)
            })
            .count()
    }
}

/// The cluster surface the chaos driver injects through. Implemented
/// by the cluster's supervisor; a trait so detector/driver tests can
/// use a recording stub.
pub trait FaultInjector: Send + Sync {
    /// Kill worker `(group, index)` now: mark it dead and make its
    /// thread exit, dropping its loaded shards.
    fn worker_crash(&self, group: usize, index: usize);
    /// Respawn worker `(group, index)` and re-ship its shards for
    /// every registered model. Returns the recovery latency in ms
    /// (respawn + re-ship, as observed by the injector).
    fn worker_restart(&self, group: usize, index: usize) -> f64;
    /// Sever a group's uplink.
    fn link_sever(&self, group: usize);
    /// Restore a severed uplink.
    fn link_heal(&self, group: usize);
    /// Degrade a group's uplink (delay ceiling + loss rate);
    /// `(0.0, 0)` heals it.
    fn uplink_degrade(&self, group: usize, delay_ms: f64, drop_per_mille: u64);
}

/// What a chaos run did: event tallies plus observed recovery
/// latencies. Two same-seed runs must produce identical tallies.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosReport {
    /// Worker crash events fired.
    pub crashes: u64,
    /// Worker restart events fired.
    pub restarts: u64,
    /// Uplink sever events fired.
    pub severs: u64,
    /// Uplink heal events fired.
    pub heals: u64,
    /// Uplink degrade events fired.
    pub degrades: u64,
    /// Per-restart recovery latency (respawn + shard re-ship), ms.
    pub recovery_ms: Vec<f64>,
}

impl ChaosReport {
    /// The determinism fingerprint: every event tally, in a fixed
    /// order. Same seed ⇒ same fingerprint.
    pub fn event_counts(&self) -> [u64; 5] {
        [
            self.crashes,
            self.restarts,
            self.severs,
            self.heals,
            self.degrades,
        ]
    }
}

/// How long the driver sleeps between clock polls while waiting for
/// the next event. Small enough to keep injection jitter ≈ 1 ms, large
/// enough not to busy-spin.
const POLL: Duration = Duration::from_millis(1);

/// Spawn the chaos driver: executes `plan` against `injector` on
/// `clock` time, firing each event once its `at_ms` passes, and
/// returns the tally through the join handle. Errors only if the OS
/// refuses to spawn the thread.
pub fn spawn(
    injector: Arc<dyn FaultInjector>,
    plan: FaultPlan,
    clock: Arc<dyn Clock>,
) -> crate::Result<thread::JoinHandle<ChaosReport>> {
    let handle = thread::Builder::new()
        .name("hiercode-chaos".into())
        .spawn(move || {
            let mut report = ChaosReport::default();
            for event in plan.events() {
                while clock.now_ms() < event.at_ms {
                    thread::sleep(POLL);
                }
                match event.action {
                    FaultAction::WorkerCrash { group, index } => {
                        injector.worker_crash(group, index);
                        report.crashes += 1;
                    }
                    FaultAction::WorkerRestart { group, index } => {
                        let ms = injector.worker_restart(group, index);
                        report.recovery_ms.push(ms);
                        report.restarts += 1;
                    }
                    FaultAction::LinkSever { group } => {
                        injector.link_sever(group);
                        report.severs += 1;
                    }
                    FaultAction::LinkHeal { group } => {
                        injector.link_heal(group);
                        report.heals += 1;
                    }
                    FaultAction::UplinkDegrade {
                        group,
                        delay_ms,
                        drop_per_mille,
                    } => {
                        injector.uplink_degrade(group, delay_ms, drop_per_mille);
                        report.degrades += 1;
                    }
                }
            }
            report
        })?;
    Ok(handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::MockClock;
    use std::sync::Mutex;

    const SUSPECT: u64 = 100;
    const DEAD: u64 = 500;

    fn det() -> FailureDetector {
        FailureDetector::new(&[3, 3], SUSPECT, DEAD, 0)
    }

    #[test]
    fn suspect_then_dead_then_revived() {
        let mut d = det();
        // Fresh: alive everywhere.
        assert_eq!(d.worker_state(0, 1, 0), Liveness::Alive);
        // Quiet past suspect: suspected, not dead.
        assert_eq!(d.worker_state(0, 1, SUSPECT), Liveness::Suspected);
        assert_eq!(d.suspected_workers(0, SUSPECT), 3);
        assert_eq!(d.alive_workers(0, SUSPECT), 3, "suspected still counts");
        // Quiet past dead: dead.
        assert_eq!(d.worker_state(0, 1, DEAD), Liveness::Dead);
        assert_eq!(d.alive_workers(0, DEAD), 0);
        // One beacon revives worker 1 (and its group).
        d.beat(0, Some(1), DEAD);
        assert_eq!(d.worker_state(0, 1, DEAD), Liveness::Alive);
        assert_eq!(d.alive_workers(0, DEAD), 1);
        assert_eq!(d.group_state(0, DEAD), Liveness::Alive);
    }

    #[test]
    fn no_false_positive_before_timeout() {
        let mut d = det();
        // Beacons every SUSPECT-1 ms: never even suspected.
        let mut now = 0;
        for _ in 0..10 {
            now += SUSPECT - 1;
            for g in 0..2 {
                for j in 0..3 {
                    assert_eq!(d.worker_state(g, j, now), Liveness::Alive);
                    d.beat(g, Some(j), now);
                }
            }
        }
        assert_eq!(d.healthy_groups(&[2, 2], now), 2);
    }

    #[test]
    fn severed_uplink_marks_whole_group() {
        let mut d = det();
        // Group 1's beacons keep flowing; group 0 goes silent at t=0
        // (severed uplink drops worker AND submaster beacons).
        let mut now = 0;
        while now < DEAD + 50 {
            now += 20;
            for j in 0..3 {
                d.beat(1, Some(j), now);
            }
            d.beat(1, None, now);
        }
        assert_eq!(d.group_state(0, now), Liveness::Dead);
        assert_eq!(
            d.alive_workers(0, now),
            0,
            "every worker behind the severed uplink ages out"
        );
        assert_eq!(d.group_state(1, now), Liveness::Alive);
        assert_eq!(d.healthy_groups(&[2, 2], now), 1);
    }

    #[test]
    fn submaster_beacon_alone_keeps_group_alive_but_not_workers() {
        let mut d = det();
        let mut now = 0;
        while now < DEAD + 50 {
            now += 20;
            d.beat(0, None, now); // submaster alive, workers silent
        }
        assert_eq!(d.group_state(0, now), Liveness::Alive);
        assert_eq!(d.alive_workers(0, now), 0);
        assert_eq!(
            d.healthy_groups(&[2, 2], now),
            0,
            "group 0 lacks k1 workers, group 1 is fully quiet"
        );
    }

    #[test]
    fn out_of_range_is_dead() {
        let d = det();
        assert_eq!(d.worker_state(9, 0, 0), Liveness::Dead);
        assert_eq!(d.group_state(9, 0), Liveness::Dead);
        assert_eq!(d.alive_workers(9, 0), 0);
    }

    /// Recording injector: logs calls, returns fixed recovery latency.
    #[derive(Default)]
    struct RecordingInjector {
        log: Mutex<Vec<String>>,
    }

    impl FaultInjector for RecordingInjector {
        fn worker_crash(&self, g: usize, j: usize) {
            self.log.lock().unwrap().push(format!("crash {g}.{j}"));
        }
        fn worker_restart(&self, g: usize, j: usize) -> f64 {
            self.log.lock().unwrap().push(format!("restart {g}.{j}"));
            1.5
        }
        fn link_sever(&self, g: usize) {
            self.log.lock().unwrap().push(format!("sever {g}"));
        }
        fn link_heal(&self, g: usize) {
            self.log.lock().unwrap().push(format!("heal {g}"));
        }
        fn uplink_degrade(&self, g: usize, d: f64, p: u64) {
            self.log.lock().unwrap().push(format!("degrade {g} {d} {p}"));
        }
    }

    #[test]
    fn driver_fires_events_in_order_on_mock_time() {
        let plan = FaultPlan::new()
            .at(10, FaultAction::WorkerCrash { group: 0, index: 1 })
            .at(
                20,
                FaultAction::UplinkDegrade {
                    group: 1,
                    delay_ms: 2.0,
                    drop_per_mille: 100,
                },
            )
            .at(30, FaultAction::WorkerRestart { group: 0, index: 1 })
            .at(40, FaultAction::LinkSever { group: 1 })
            .at(50, FaultAction::LinkHeal { group: 1 });
        let injector = Arc::new(RecordingInjector::default());
        let clock = Arc::new(MockClock::new());
        let h = spawn(
            Arc::clone(&injector) as Arc<dyn FaultInjector>,
            plan,
            Arc::clone(&clock) as Arc<dyn Clock>,
        )
        .expect("spawn driver");
        // Advance mock time past every event; the driver polls.
        clock.set(60);
        let report = h.join().expect("driver exits");
        assert_eq!(report.event_counts(), [1, 1, 1, 1, 1]);
        assert_eq!(report.recovery_ms, vec![1.5]);
        assert_eq!(
            *injector.log.lock().unwrap(),
            vec![
                "crash 0.1",
                "degrade 1 2 100",
                "restart 0.1",
                "sever 1",
                "heal 1",
            ]
        );
    }
}
