//! Cluster metrics: counters, admission gauges and latency histograms,
//! shared across coordinator threads.
//!
//! Latencies are log2-bucket [`Histogram`]s (1µs..~4000s), so job *and*
//! decode latency expose p50/p95/p99 — tails, not just means — and the
//! serving layer's admission behavior is observable: `queue_depth` is
//! the live number of accepted-but-undispatched requests, `rejected`
//! counts `Busy` bounces, `shed` counts deadline expiries.

use crate::sync::Mutex;
use crate::util::stats::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};

/// Sentinel for the liveness gauges: "the failure detector has never
/// swept this group", distinct from a real reading of zero.
const LIVENESS_UNTRACKED: u64 = u64::MAX;

/// Per-group counters: arrivals and decode activity of one group
/// (rack), so heterogeneous topologies are observable group by group.
#[derive(Debug)]
struct GroupCounters {
    /// Worker (sub-)results that arrived at this group's submaster.
    products: AtomicU64,
    /// Intra-group decodes this group performed.
    decodes: AtomicU64,
    /// Straggler partial work harvested: sub-results consumed by this
    /// group's decodes that came from workers which had not finished
    /// all their sub-tasks (always 0 in the all-or-nothing model).
    partials: AtomicU64,
    /// Workers not classified Dead by the failure detector (gauge;
    /// [`LIVENESS_UNTRACKED`] until the first sweep).
    alive_workers: AtomicU64,
    /// Workers currently Suspected (gauge; [`LIVENESS_UNTRACKED`]
    /// until the first sweep).
    suspected: AtomicU64,
    /// Group-decode session latency.
    decode_latency: Mutex<Histogram>,
}

impl Default for GroupCounters {
    fn default() -> Self {
        Self {
            products: AtomicU64::new(0),
            decodes: AtomicU64::new(0),
            partials: AtomicU64::new(0),
            alive_workers: AtomicU64::new(LIVENESS_UNTRACKED),
            suspected: AtomicU64::new(LIVENESS_UNTRACKED),
            decode_latency: Mutex::default(),
        }
    }
}

/// Shared metrics sink. Counters are lock-free; histograms take a
/// short mutex (recorded once per job, not per message).
#[derive(Debug, Default)]
pub struct Metrics {
    /// Client requests accepted (past admission control).
    pub requests: AtomicU64,
    /// Batched jobs dispatched.
    pub jobs: AtomicU64,
    /// Jobs completed successfully.
    pub completed: AtomicU64,
    /// Jobs failed (insufficient groups, decode error).
    pub failed: AtomicU64,
    /// Jobs cancelled (every client abandoned them before completion).
    pub cancelled: AtomicU64,
    /// Submissions bounced with `Busy` (admission queue full).
    pub rejected: AtomicU64,
    /// Requests shed because their deadline expired while queued.
    pub shed: AtomicU64,
    /// Requests currently accepted but not yet dispatched (gauge).
    pub queue_depth: AtomicU64,
    /// Worker (sub-)results computed.
    pub worker_products: AtomicU64,
    /// Worker (sub-)results discarded (arrived after their group
    /// decoded or after the job's state was garbage-collected).
    pub late_products: AtomicU64,
    /// Partials that reached the master after its job was already
    /// complete/cancelled — including after the job's `Done` tombstone
    /// was garbage-collected (a late delivery either way, never a
    /// silent unknown-job drop).
    pub late_partials: AtomicU64,
    /// Intra-group decodes performed.
    pub group_decodes: AtomicU64,
    /// Total decode flops (intra + cross), for §IV accounting.
    pub decode_flops: AtomicU64,
    /// Transport bytes shipped downstream (socket mode; 0 in-memory).
    /// Paired with `transport_bytes_received`.
    pub transport_bytes_sent: AtomicU64,
    /// Transport bytes received upstream. Paired with
    /// `transport_bytes_sent`.
    pub transport_bytes_received: AtomicU64,
    /// Frames shipped downstream. Paired with
    /// `transport_frames_received`.
    pub transport_frames_sent: AtomicU64,
    /// Frames received upstream. Paired with `transport_frames_sent`.
    pub transport_frames_received: AtomicU64,
    /// Node connections re-established after a loss (the initial
    /// connect does not count).
    pub transport_reconnects: AtomicU64,
    /// Handshakes that ended in a `Reject` or a protocol/IO failure.
    pub transport_handshake_failures: AtomicU64,
    /// Artifact rollouts completed by the control plane.
    pub rollouts: AtomicU64,
    /// Artifact rollbacks completed by the control plane.
    pub rollbacks: AtomicU64,
    /// Artifact generation currently served (gauge; stored by the
    /// cluster at launch and after every rollout/rollback — 0 only on
    /// a bare `Metrics` with no cluster behind it).
    pub artifact_generation: AtomicU64,
    /// End-to-end request latency (submit → reply).
    latency: Mutex<Histogram>,
    /// Decode-only latency at the master.
    decode_latency: Mutex<Histogram>,
    /// Per-group counters (empty when the group count is unknown —
    /// unit tests driving a submaster directly).
    groups: Vec<GroupCounters>,
}

impl Metrics {
    /// Fresh metrics with no per-group breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fresh metrics tracking `n_groups` groups — what the cluster
    /// creates so heterogeneous runs are observable per group.
    pub fn with_groups(n_groups: usize) -> Self {
        Self {
            groups: (0..n_groups).map(|_| GroupCounters::default()).collect(),
            ..Self::default()
        }
    }

    /// Record one end-to-end request latency.
    pub fn record_latency(&self, seconds: f64) {
        self.latency.lock().record(seconds);
    }

    /// Record one master-side decode latency.
    pub fn record_decode_latency(&self, seconds: f64) {
        self.decode_latency.lock().record(seconds);
    }

    /// Count one worker product arriving at `group`'s submaster
    /// (no-op for out-of-range groups — untracked contexts).
    pub fn record_group_product(&self, group: usize) {
        if let Some(g) = self.groups.get(group) {
            g.products.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one intra-group decode of `group` with its session
    /// latency in seconds.
    pub fn record_group_decode(&self, group: usize, seconds: f64) {
        if let Some(g) = self.groups.get(group) {
            g.decodes.fetch_add(1, Ordering::Relaxed);
            g.decode_latency.lock().record(seconds);
        }
    }

    /// Count `n` straggler sub-results harvested by one of `group`'s
    /// decodes (no-op for out-of-range groups — untracked contexts).
    pub fn record_group_partials(&self, group: usize, n: u64) {
        if let Some(g) = self.groups.get(group) {
            g.partials.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Publish the failure detector's view of `group` after a sweep:
    /// how many workers are not Dead and how many are Suspected
    /// (no-op for out-of-range groups — untracked contexts).
    pub fn set_group_liveness(&self, group: usize, alive: u64, suspected: u64) {
        if let Some(g) = self.groups.get(group) {
            g.alive_workers.store(alive, Ordering::Relaxed);
            g.suspected.store(suspected, Ordering::Relaxed);
        }
    }

    /// Snapshot for reporting. The per-model breakdown is overlaid by
    /// `ClusterCore::metrics` (the model table lives in the service
    /// state, not here); `models` is empty on a bare snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        // Lock order (acyclic, documented for the lock-discipline
        // lint): latency → decode_latency → per-group latency. No
        // other path takes more than one of these at a time.
        let lat = self.latency.lock();
        let dec = self.decode_latency.lock();
        let per_group = self
            .groups
            .iter()
            .map(|g| {
                let glat = g.decode_latency.lock();
                let gauge = |a: &AtomicU64| match a.load(Ordering::Relaxed) {
                    LIVENESS_UNTRACKED => None,
                    v => Some(v),
                };
                GroupMetricsSnapshot {
                    products: g.products.load(Ordering::Relaxed),
                    decodes: g.decodes.load(Ordering::Relaxed),
                    partials_used: g.partials.load(Ordering::Relaxed),
                    alive_workers: gauge(&g.alive_workers),
                    suspected: gauge(&g.suspected),
                    decode_mean: glat.mean(),
                    // Per-link transport counters live hub-side; the
                    // cluster overlays them (0 on a bare snapshot).
                    ..GroupMetricsSnapshot::default()
                }
            })
            .collect();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            jobs: self.jobs.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            worker_products: self.worker_products.load(Ordering::Relaxed),
            late_products: self.late_products.load(Ordering::Relaxed),
            late_partials: self.late_partials.load(Ordering::Relaxed),
            group_decodes: self.group_decodes.load(Ordering::Relaxed),
            decode_flops: self.decode_flops.load(Ordering::Relaxed),
            transport_bytes_sent: self.transport_bytes_sent.load(Ordering::Relaxed),
            transport_bytes_received: self.transport_bytes_received.load(Ordering::Relaxed),
            transport_frames_sent: self.transport_frames_sent.load(Ordering::Relaxed),
            transport_frames_received: self.transport_frames_received.load(Ordering::Relaxed),
            transport_reconnects: self.transport_reconnects.load(Ordering::Relaxed),
            transport_handshake_failures: self
                .transport_handshake_failures
                .load(Ordering::Relaxed),
            rollouts: self.rollouts.load(Ordering::Relaxed),
            rollbacks: self.rollbacks.load(Ordering::Relaxed),
            artifact_generation: self.artifact_generation.load(Ordering::Relaxed),
            latency_mean: lat.mean(),
            latency_p50: lat.quantile(0.5),
            latency_p95: lat.quantile(0.95),
            latency_p99: lat.quantile(0.99),
            decode_mean: dec.mean(),
            decode_p50: dec.quantile(0.5),
            decode_p95: dec.quantile(0.95),
            decode_p99: dec.quantile(0.99),
            per_group,
            models: Vec::new(),
            decode_cache_hits: 0,
            decode_cache_misses: 0,
            decode_cache_evictions: 0,
            decode_cache_hit_rate: f64::NAN,
        }
    }

    /// Bump a counter.
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Decrement a gauge (callers only release what they reserved).
    /// Saturates at zero — an unpaired release must not wrap the gauge
    /// to `u64::MAX` (the double-shed symptom) — and debug builds
    /// assert the invariant so the unpaired caller is caught in tests.
    pub fn dec(counter: &AtomicU64) {
        // fetch_update with a total closure cannot return Err; default
        // rather than unwrap so the gauge path stays panic-free.
        let prev = counter
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            })
            .unwrap_or(0);
        debug_assert!(prev > 0, "gauge decremented below zero (unpaired release)");
    }

    /// Add to a counter.
    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }
}

/// Point-in-time view of one group's counters.
#[derive(Clone, Debug, Default)]
pub struct GroupMetricsSnapshot {
    /// Worker (sub-)results that arrived at this group's submaster.
    pub products: u64,
    /// Intra-group decodes this group performed.
    pub decodes: u64,
    /// Straggler partial work harvested across this group's decodes:
    /// sub-results used that came from workers which never finished
    /// all their sub-tasks (0 in the all-or-nothing model).
    pub partials_used: u64,
    /// Workers the failure detector does not consider Dead, or `None`
    /// when liveness tracking is off / has not swept yet.
    pub alive_workers: Option<u64>,
    /// Workers currently Suspected, or `None` when untracked.
    pub suspected: Option<u64>,
    /// Mean group-decode session latency (s).
    pub decode_mean: f64,
    /// Transport bytes shipped to this group's node (socket mode;
    /// overlaid by `ClusterCore::metrics` from the hub's per-link
    /// counters, 0 otherwise). Paired with `transport_bytes_received`.
    pub transport_bytes_sent: u64,
    /// Transport bytes received from this group's node. Paired with
    /// `transport_bytes_sent`.
    pub transport_bytes_received: u64,
    /// Frames shipped to this group's node. Paired with
    /// `transport_frames_received`.
    pub transport_frames_sent: u64,
    /// Frames received from this group's node. Paired with
    /// `transport_frames_sent`.
    pub transport_frames_received: u64,
    /// Reconnects completed on this group's link.
    pub transport_reconnects: u64,
}

/// Point-in-time view of one model's admission counters.
#[derive(Clone, Debug, Default)]
pub struct ModelMetricsSnapshot {
    /// Registered model name.
    pub name: String,
    /// Requests accepted but not yet dispatched (gauge).
    pub queued: u64,
    /// Requests accepted.
    pub accepted: u64,
    /// Submissions bounced with `Busy`.
    pub rejected: u64,
    /// Requests shed on deadline expiry.
    pub shed: u64,
    /// Requests answered successfully.
    pub completed: u64,
}

/// Point-in-time view of [`Metrics`].
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Client requests accepted.
    pub requests: u64,
    /// Batched jobs dispatched.
    pub jobs: u64,
    /// Jobs completed.
    pub completed: u64,
    /// Jobs failed.
    pub failed: u64,
    /// Jobs cancelled (abandoned by every client).
    pub cancelled: u64,
    /// Submissions bounced with `Busy`.
    pub rejected: u64,
    /// Requests shed on deadline expiry.
    pub shed: u64,
    /// Requests currently queued ahead of dispatch (gauge).
    pub queue_depth: u64,
    /// Worker (sub-)results computed.
    pub worker_products: u64,
    /// Late (discarded) products.
    pub late_products: u64,
    /// Partials that reached the master after its job completed (or
    /// after the job's tombstone was garbage-collected).
    pub late_partials: u64,
    /// Intra-group decodes.
    pub group_decodes: u64,
    /// Total decode flops.
    pub decode_flops: u64,
    /// Transport bytes shipped downstream (socket mode; 0 in-memory).
    pub transport_bytes_sent: u64,
    /// Transport bytes received upstream.
    pub transport_bytes_received: u64,
    /// Frames shipped downstream.
    pub transport_frames_sent: u64,
    /// Frames received upstream.
    pub transport_frames_received: u64,
    /// Node connections re-established after a loss.
    pub transport_reconnects: u64,
    /// Handshakes that failed (rejects and protocol/IO failures).
    pub transport_handshake_failures: u64,
    /// Artifact rollouts completed by the control plane.
    pub rollouts: u64,
    /// Artifact rollbacks completed by the control plane.
    pub rollbacks: u64,
    /// Artifact generation currently served (gauge; 0 on a bare
    /// snapshot with no cluster behind it).
    pub artifact_generation: u64,
    /// Mean end-to-end latency (s).
    pub latency_mean: f64,
    /// Median end-to-end latency (s).
    pub latency_p50: f64,
    /// p95 end-to-end latency (s).
    pub latency_p95: f64,
    /// p99 end-to-end latency (s).
    pub latency_p99: f64,
    /// Mean master decode latency (s).
    pub decode_mean: f64,
    /// Median master decode latency (s).
    pub decode_p50: f64,
    /// p95 master decode latency (s).
    pub decode_p95: f64,
    /// p99 master decode latency (s).
    pub decode_p99: f64,
    /// Per-group arrival / decode breakdown, in group-index order
    /// (empty when the metrics were created without a group count).
    pub per_group: Vec<GroupMetricsSnapshot>,
    /// Per-model admission breakdown, sorted by name (filled by
    /// `ClusterCore::metrics`; empty on a bare `Metrics::snapshot`).
    pub models: Vec<ModelMetricsSnapshot>,
    /// Decode LU-cache lookups that skipped factorization, aggregated
    /// across the scheme's caches (filled by `ClusterCore::metrics`
    /// from [`crate::linalg::LuCache::stats`]; 0 on a bare snapshot).
    pub decode_cache_hits: u64,
    /// Decode LU-cache lookups that had to factorize (filled by
    /// `ClusterCore::metrics`; 0 on a bare snapshot).
    pub decode_cache_misses: u64,
    /// Decode LU-cache entries dropped — LRU pressure or invalidation
    /// on model registration / worker restart (filled by
    /// `ClusterCore::metrics`; 0 on a bare snapshot).
    pub decode_cache_evictions: u64,
    /// Hit rate `hits / (hits + misses)` in `[0, 1]`, or the NaN
    /// "no lookups yet" sentinel (same convention as the latency
    /// histograms; serialized as `null`, displayed as `n/a`).
    pub decode_cache_hit_rate: f64,
}

/// JSON number, or `null` for the NaN sentinel an empty histogram
/// reports — the BENCH files' convention for "no data", kept distinct
/// from a real measured zero.
fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9e}")
    } else {
        "null".to_string()
    }
}

/// JSON liveness gauge: `null` while untracked, the count otherwise.
fn jgauge(v: Option<u64>) -> String {
    match v {
        Some(v) => v.to_string(),
        None => "null".to_string(),
    }
}

impl MetricsSnapshot {
    /// Render the snapshot as a JSON object (counters, latency
    /// quantiles, per-group breakdown with the liveness gauges).
    /// Non-finite latencies and untracked gauges serialize as `null`,
    /// mirroring the `n/a` sentinel in [`Display`](std::fmt::Display);
    /// the output parses with [`crate::config::json::Json::parse`].
    pub fn to_json(&self) -> String {
        let per_group: Vec<String> = self
            .per_group
            .iter()
            .map(|g| {
                format!(
                    "{{\"products\": {}, \"decodes\": {}, \"partials_used\": {}, \
                     \"alive_workers\": {}, \"suspected\": {}, \"decode_mean_s\": {}, \
                     \"transport_bytes_sent\": {}, \"transport_bytes_received\": {}, \
                     \"transport_frames_sent\": {}, \"transport_frames_received\": {}, \
                     \"transport_reconnects\": {}}}",
                    g.products,
                    g.decodes,
                    g.partials_used,
                    jgauge(g.alive_workers),
                    jgauge(g.suspected),
                    jnum(g.decode_mean),
                    g.transport_bytes_sent,
                    g.transport_bytes_received,
                    g.transport_frames_sent,
                    g.transport_frames_received,
                    g.transport_reconnects
                )
            })
            .collect();
        let models: Vec<String> = self
            .models
            .iter()
            .map(|m| {
                format!(
                    "{{\"name\": {:?}, \"queued\": {}, \"accepted\": {}, \
                     \"rejected\": {}, \"shed\": {}, \"completed\": {}}}",
                    m.name, m.queued, m.accepted, m.rejected, m.shed, m.completed
                )
            })
            .collect();
        format!(
            "{{\n  \"requests\": {}, \"jobs\": {}, \"completed\": {}, \"failed\": {}, \
             \"cancelled\": {}, \"rejected\": {}, \"shed\": {}, \"queue_depth\": {},\n  \
             \"worker_products\": {}, \"late_products\": {}, \"late_partials\": {}, \
             \"group_decodes\": {}, \"decode_flops\": {},\n  \
             \"transport_bytes_sent\": {}, \"transport_bytes_received\": {}, \
             \"transport_frames_sent\": {}, \"transport_frames_received\": {}, \
             \"transport_reconnects\": {}, \"transport_handshake_failures\": {},\n  \
             \"rollouts\": {}, \"rollbacks\": {}, \"artifact_generation\": {},\n  \
             \"latency_mean_s\": {}, \"latency_p50_s\": {}, \"latency_p95_s\": {}, \
             \"latency_p99_s\": {},\n  \
             \"decode_mean_s\": {}, \"decode_p50_s\": {}, \"decode_p95_s\": {}, \
             \"decode_p99_s\": {},\n  \
             \"decode_cache_hits\": {}, \"decode_cache_misses\": {}, \
             \"decode_cache_evictions\": {}, \"decode_cache_hit_rate\": {},\n  \
             \"per_group\": [{}],\n  \"models\": [{}]\n}}",
            self.requests,
            self.jobs,
            self.completed,
            self.failed,
            self.cancelled,
            self.rejected,
            self.shed,
            self.queue_depth,
            self.worker_products,
            self.late_products,
            self.late_partials,
            self.group_decodes,
            self.decode_flops,
            self.transport_bytes_sent,
            self.transport_bytes_received,
            self.transport_frames_sent,
            self.transport_frames_received,
            self.transport_reconnects,
            self.transport_handshake_failures,
            self.rollouts,
            self.rollbacks,
            self.artifact_generation,
            jnum(self.latency_mean),
            jnum(self.latency_p50),
            jnum(self.latency_p95),
            jnum(self.latency_p99),
            jnum(self.decode_mean),
            jnum(self.decode_p50),
            jnum(self.decode_p95),
            jnum(self.decode_p99),
            self.decode_cache_hits,
            self.decode_cache_misses,
            self.decode_cache_evictions,
            jnum(self.decode_cache_hit_rate),
            per_group.join(", "),
            models.join(", ")
        )
    }
}

/// Render a `[0, 1]` rate as a percentage, or `n/a` for the NaN
/// "no data yet" sentinel.
fn fmt_rate(rate: f64) -> String {
    if rate.is_finite() {
        format!("{:.1}%", rate * 100.0)
    } else {
        "n/a".to_string()
    }
}

/// Render a latency in milliseconds, or `n/a` for the NaN sentinel an
/// empty histogram reports (never a fake `0.000ms`).
fn fmt_ms(seconds: f64) -> String {
    if seconds.is_finite() {
        format!("{:.3}ms", seconds * 1e3)
    } else {
        "n/a".to_string()
    }
}

/// Render a liveness gauge, or `n/a` when the detector has never swept
/// (never a fake `0` — same convention as the NaN latency sentinel).
fn fmt_gauge(v: Option<u64>) -> String {
    match v {
        Some(v) => v.to_string(),
        None => "n/a".to_string(),
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "requests:        {}", self.requests)?;
        writeln!(
            f,
            "admission:       {} rejected (busy), {} shed (deadline), {} queued now",
            self.rejected, self.shed, self.queue_depth
        )?;
        writeln!(
            f,
            "jobs:            {} ({} completed, {} failed, {} cancelled)",
            self.jobs, self.completed, self.failed, self.cancelled
        )?;
        writeln!(
            f,
            "worker products: {} ({} late/discarded, {} late partials)",
            self.worker_products, self.late_products, self.late_partials
        )?;
        writeln!(f, "group decodes:   {}", self.group_decodes)?;
        writeln!(f, "decode flops:    {}", self.decode_flops)?;
        writeln!(
            f,
            "transport:       {} B out / {} B in, {} frames out / {} frames in, \
             {} reconnects, {} handshake failures",
            self.transport_bytes_sent,
            self.transport_bytes_received,
            self.transport_frames_sent,
            self.transport_frames_received,
            self.transport_reconnects,
            self.transport_handshake_failures
        )?;
        writeln!(
            f,
            "control plane:   generation {}, {} rollouts, {} rollbacks",
            self.artifact_generation, self.rollouts, self.rollbacks
        )?;
        writeln!(
            f,
            "latency:         mean {}  p50 {}  p95 {}  p99 {}",
            fmt_ms(self.latency_mean),
            fmt_ms(self.latency_p50),
            fmt_ms(self.latency_p95),
            fmt_ms(self.latency_p99)
        )?;
        writeln!(
            f,
            "decode latency:  mean {}  p50 {}  p95 {}  p99 {}",
            fmt_ms(self.decode_mean),
            fmt_ms(self.decode_p50),
            fmt_ms(self.decode_p95),
            fmt_ms(self.decode_p99)
        )?;
        write!(
            f,
            "decode cache:    {} hits, {} misses, {} evictions, hit rate {}",
            self.decode_cache_hits,
            self.decode_cache_misses,
            self.decode_cache_evictions,
            fmt_rate(self.decode_cache_hit_rate)
        )?;
        for (g, gm) in self.per_group.iter().enumerate() {
            write!(
                f,
                "\ngroup {g}:         {} products, {} decodes, {} partials used, \
                 decode mean {}, alive {}, suspected {}, link {} B out / {} B in \
                 ({}/{} frames, {} reconnects)",
                gm.products,
                gm.decodes,
                gm.partials_used,
                fmt_ms(gm.decode_mean),
                fmt_gauge(gm.alive_workers),
                fmt_gauge(gm.suspected),
                gm.transport_bytes_sent,
                gm.transport_bytes_received,
                gm.transport_frames_sent,
                gm.transport_frames_received,
                gm.transport_reconnects
            )?;
        }
        for m in &self.models {
            write!(
                f,
                "\nmodel {:<10} {} accepted, {} completed, {} rejected, {} shed, {} queued",
                m.name, m.accepted, m.completed, m.rejected, m.shed, m.queued
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_group_counters_tracked_and_out_of_range_ignored() {
        let m = Metrics::with_groups(2);
        m.record_group_product(0);
        m.record_group_product(0);
        m.record_group_product(1);
        m.record_group_decode(1, 0.004);
        m.record_group_partials(1, 3);
        // Out-of-range group index is a no-op, never a panic.
        m.record_group_product(9);
        m.record_group_decode(9, 1.0);
        m.record_group_partials(9, 5);
        let s = m.snapshot();
        assert_eq!(s.per_group.len(), 2);
        assert_eq!(s.per_group[0].products, 2);
        assert_eq!(s.per_group[0].decodes, 0);
        assert_eq!(s.per_group[0].partials_used, 0);
        assert_eq!(s.per_group[1].products, 1);
        assert_eq!(s.per_group[1].decodes, 1);
        assert_eq!(s.per_group[1].partials_used, 3);
        assert!((s.per_group[1].decode_mean - 0.004).abs() < 1e-12);
        assert!(format!("{s}").contains("group 1:"));
        // Metrics::new() has no per-group breakdown.
        assert!(Metrics::new().snapshot().per_group.is_empty());
    }

    #[test]
    fn liveness_gauges_untracked_until_first_sweep() {
        let m = Metrics::with_groups(2);
        let s = m.snapshot();
        // Before any sweep the gauges are the untracked sentinel, and
        // Display must say so rather than fake an `alive 0` outage.
        assert_eq!(s.per_group[0].alive_workers, None);
        assert_eq!(s.per_group[0].suspected, None);
        assert!(format!("{s}").contains("alive n/a, suspected n/a"));
        m.set_group_liveness(0, 3, 1);
        m.set_group_liveness(9, 5, 5); // out of range: no-op, no panic
        let s = m.snapshot();
        assert_eq!(s.per_group[0].alive_workers, Some(3));
        assert_eq!(s.per_group[0].suspected, Some(1));
        assert_eq!(s.per_group[1].alive_workers, None);
        assert!(format!("{s}").contains("alive 3, suspected 1"));
    }

    #[test]
    fn snapshot_json_parses_with_null_sentinels() {
        let m = Metrics::with_groups(2);
        Metrics::inc(&m.requests);
        m.set_group_liveness(0, 4, 0);
        let text = m.snapshot().to_json();
        let v = crate::config::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(v.get("requests").and_then(|j| j.as_usize()), Some(1));
        // Empty histograms are null, not 0.0 — same rule as BENCH files.
        assert!(matches!(
            v.get("latency_p99_s"),
            Some(crate::config::json::Json::Null)
        ));
        let groups = match v.get("per_group") {
            Some(crate::config::json::Json::Array(a)) => a,
            other => panic!("per_group missing: {other:?}"),
        };
        assert_eq!(groups.len(), 2);
        assert_eq!(
            groups[0].get("alive_workers").and_then(|j| j.as_usize()),
            Some(4)
        );
        // Group 1 was never swept: its gauges are null, not 0.
        assert!(matches!(
            groups[1].get("alive_workers"),
            Some(crate::config::json::Json::Null)
        ));
    }

    #[test]
    fn counters_and_snapshot() {
        let m = Metrics::new();
        Metrics::inc(&m.requests);
        Metrics::inc(&m.requests);
        Metrics::add(&m.decode_flops, 100);
        m.record_latency(0.002);
        m.record_latency(0.004);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.decode_flops, 100);
        assert!((s.latency_mean - 0.003).abs() < 1e-9);
        assert!(!format!("{s}").is_empty());
    }

    #[test]
    fn admission_counters_and_gauge() {
        let m = Metrics::new();
        Metrics::inc(&m.rejected);
        Metrics::inc(&m.shed);
        Metrics::inc(&m.queue_depth);
        Metrics::inc(&m.queue_depth);
        Metrics::dec(&m.queue_depth);
        let s = m.snapshot();
        assert_eq!(s.rejected, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.queue_depth, 1);
        assert!(format!("{s}").contains("rejected"));
    }

    #[test]
    fn empty_histograms_report_nan_not_fake_zero_latency() {
        // Satellite regression: before any request completes, p50/p95/
        // p99 must be the NaN sentinel — a 0.0 here is a fake "zero
        // latency" tail that serializers would happily report.
        let s = Metrics::new().snapshot();
        assert!(s.latency_mean.is_nan(), "mean={}", s.latency_mean);
        assert!(s.latency_p50.is_nan(), "p50={}", s.latency_p50);
        assert!(s.latency_p95.is_nan());
        assert!(s.latency_p99.is_nan());
        assert!(s.decode_mean.is_nan());
        assert!(s.decode_p50.is_nan());
        assert!(s.decode_p99.is_nan());
        let rendered = format!("{s}");
        assert!(rendered.contains("n/a"), "Display must not fake 0.000ms");
        assert!(
            !rendered.contains("p99 0.000ms"),
            "empty histogram must never render as zero latency"
        );
    }

    #[test]
    fn decode_cache_fields_default_to_no_data_sentinels() {
        // A bare snapshot has no cache overlay: zero counters and the
        // NaN hit-rate sentinel — Display says n/a, JSON says null.
        let s = Metrics::new().snapshot();
        assert_eq!(s.decode_cache_hits, 0);
        assert_eq!(s.decode_cache_misses, 0);
        assert_eq!(s.decode_cache_evictions, 0);
        assert!(s.decode_cache_hit_rate.is_nan());
        assert!(format!("{s}").contains("hit rate n/a"));
        let v = crate::config::json::Json::parse(&s.to_json()).expect("valid JSON");
        assert!(matches!(
            v.get("decode_cache_hit_rate"),
            Some(crate::config::json::Json::Null)
        ));
        assert_eq!(
            v.get("decode_cache_hits").and_then(|j| j.as_usize()),
            Some(0)
        );
        // Overlaid values render as a percentage.
        let mut s = s;
        s.decode_cache_hits = 9;
        s.decode_cache_misses = 1;
        s.decode_cache_hit_rate = 0.9;
        assert!(format!("{s}").contains("hit rate 90.0%"));
        let v = crate::config::json::Json::parse(&s.to_json()).expect("valid JSON");
        assert_eq!(
            v.get("decode_cache_misses").and_then(|j| j.as_usize()),
            Some(1)
        );
    }

    #[test]
    fn transport_counters_surface_in_snapshot_json_and_display() {
        let m = Metrics::with_groups(1);
        Metrics::add(&m.transport_bytes_sent, 128);
        Metrics::add(&m.transport_bytes_received, 64);
        Metrics::inc(&m.transport_frames_sent);
        Metrics::inc(&m.transport_frames_received);
        Metrics::inc(&m.transport_reconnects);
        Metrics::inc(&m.transport_handshake_failures);
        let mut s = m.snapshot();
        assert_eq!(s.transport_bytes_sent, 128);
        assert_eq!(s.transport_bytes_received, 64);
        assert_eq!(s.transport_frames_sent, 1);
        assert_eq!(s.transport_frames_received, 1);
        assert_eq!(s.transport_reconnects, 1);
        assert_eq!(s.transport_handshake_failures, 1);
        // Per-group breakdown is an overlay; bare snapshots read 0.
        assert_eq!(s.per_group[0].transport_bytes_sent, 0);
        s.per_group[0].transport_bytes_sent = 100;
        s.per_group[0].transport_bytes_received = 50;
        s.per_group[0].transport_frames_sent = 2;
        s.per_group[0].transport_frames_received = 3;
        s.per_group[0].transport_reconnects = 1;
        let rendered = format!("{s}");
        assert!(rendered.contains("128 B out / 64 B in"));
        assert!(rendered.contains("100 B out / 50 B in (2/3 frames, 1 reconnects)"));
        let v = crate::config::json::Json::parse(&s.to_json()).expect("valid JSON");
        assert_eq!(
            v.get("transport_bytes_sent").and_then(|j| j.as_usize()),
            Some(128)
        );
        assert_eq!(
            v.get("transport_handshake_failures")
                .and_then(|j| j.as_usize()),
            Some(1)
        );
        let groups = match v.get("per_group") {
            Some(crate::config::json::Json::Array(a)) => a,
            other => panic!("per_group missing: {other:?}"),
        };
        assert_eq!(
            groups[0]
                .get("transport_bytes_received")
                .and_then(|j| j.as_usize()),
            Some(50)
        );
        assert_eq!(
            groups[0]
                .get("transport_reconnects")
                .and_then(|j| j.as_usize()),
            Some(1)
        );
    }

    #[test]
    fn control_plane_counters_surface_in_snapshot_json_and_display() {
        let m = Metrics::new();
        Metrics::inc(&m.rollouts);
        Metrics::inc(&m.rollouts);
        Metrics::inc(&m.rollbacks);
        m.artifact_generation.store(3, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.rollouts, 2);
        assert_eq!(s.rollbacks, 1);
        assert_eq!(s.artifact_generation, 3);
        assert!(format!("{s}")
            .contains("generation 3, 2 rollouts, 1 rollbacks"));
        let v = crate::config::json::Json::parse(&s.to_json()).expect("valid JSON");
        assert_eq!(v.get("rollouts").and_then(|j| j.as_usize()), Some(2));
        assert_eq!(v.get("rollbacks").and_then(|j| j.as_usize()), Some(1));
        assert_eq!(
            v.get("artifact_generation").and_then(|j| j.as_usize()),
            Some(3)
        );
    }

    #[test]
    fn gauge_dec_saturates_at_zero() {
        let m = Metrics::new();
        Metrics::inc(&m.queue_depth);
        Metrics::dec(&m.queue_depth);
        assert_eq!(m.snapshot().queue_depth, 0);
        // Release builds: an unpaired release clamps at 0 instead of
        // wrapping the gauge to u64::MAX. (Debug builds catch the
        // unpaired caller via debug_assert, so the clamp branch is
        // only reachable here.)
        #[cfg(not(debug_assertions))]
        {
            Metrics::dec(&m.queue_depth);
            assert_eq!(m.snapshot().queue_depth, 0, "unpaired dec must clamp");
        }
    }

    #[test]
    fn latency_quantiles_from_histogram() {
        let m = Metrics::new();
        // 90 fast (≈1ms) + 10 slow (≈100ms) requests: p50 stays in the
        // fast bucket, p99 lands in the slow one.
        for _ in 0..90 {
            m.record_latency(0.001);
            m.record_decode_latency(0.001);
        }
        for _ in 0..10 {
            m.record_latency(0.1);
            m.record_decode_latency(0.1);
        }
        let s = m.snapshot();
        assert!(s.latency_p50 < 0.01, "p50={}", s.latency_p50);
        assert!(s.latency_p99 >= 0.05, "p99={}", s.latency_p99);
        assert!(s.decode_p50 < 0.01, "decode p50={}", s.decode_p50);
        assert!(s.decode_p99 >= 0.05, "decode p99={}", s.decode_p99);
        assert!(s.latency_p95 >= s.latency_p50);
        assert!(s.decode_p95 >= s.decode_p50);
    }
}
