//! Master thread: the job state machine at the root of Fig. 1.
//!
//! Broadcasts batched jobs to all submasters, collects group results,
//! and at the `k2`-th delivery performs the **cross-group decode**
//! (recovering `A·X`), splits the batch back into per-request columns,
//! and fans the replies out. Late group deliveries are discarded.

use crate::coding::HierarchicalCode;
use crate::coordinator::messages::{
    JobBroadcast, JobId, MasterMsg, ReplyRoute, SubmasterMsg,
};
use crate::coordinator::metrics::Metrics;
use crate::linalg::Matrix;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

struct JobState {
    /// Collected `(group, Ã_i·X)` results.
    groups: Vec<(usize, Matrix)>,
    /// Reply routing (one per batched request column).
    replies: Vec<ReplyRoute>,
    /// Set once decoded.
    done: bool,
    /// Dispatch time (for job-level latency).
    dispatched_at: Instant,
}

/// Spawn the master thread.
pub fn spawn(
    code: Arc<HierarchicalCode>,
    submasters: Vec<mpsc::Sender<SubmasterMsg>>,
    out_rows: usize,
    metrics: Arc<Metrics>,
    rx: mpsc::Receiver<MasterMsg>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name("hiercode-master".to_string())
        .spawn(move || {
            let k2 = code.params().k2;
            let mut jobs: HashMap<JobId, JobState> = HashMap::new();
            while let Ok(msg) = rx.recv() {
                match msg {
                    MasterMsg::Shutdown => {
                        for sm in &submasters {
                            let _ = sm.send(SubmasterMsg::Shutdown);
                        }
                        break;
                    }
                    MasterMsg::Batch { job, replies } => {
                        Metrics::inc(&metrics.jobs);
                        jobs.insert(
                            job.id,
                            JobState {
                                groups: Vec::with_capacity(k2),
                                replies,
                                done: false,
                                dispatched_at: Instant::now(),
                            },
                        );
                        for sm in &submasters {
                            let _ = sm.send(SubmasterMsg::Job(JobBroadcast {
                                id: job.id,
                                x: Arc::clone(&job.x),
                            }));
                        }
                    }
                    MasterMsg::Group(gr) => {
                        let Some(state) = jobs.get_mut(&gr.id) else {
                            continue; // late delivery for a finished job
                        };
                        if state.done {
                            continue;
                        }
                        state.groups.push((gr.group, gr.data));
                        if state.groups.len() < k2 {
                            continue;
                        }
                        state.done = true;
                        // k2-th fastest group arrived: cross-group decode.
                        let t0 = Instant::now();
                        let decode = code.decode_cross(&state.groups);
                        match decode {
                            Ok((result, flops)) => {
                                Metrics::add(&metrics.decode_flops, flops);
                                metrics.record_decode_latency(t0.elapsed().as_secs_f64());
                                debug_assert_eq!(result.rows(), out_rows);
                                // Count completion *before* fanning out so
                                // clients never observe a reply while the
                                // job still reads as in-flight.
                                Metrics::inc(&metrics.completed);
                                // Fan out per-request columns.
                                for route in &state.replies {
                                    let col: Vec<f64> = (0..result.rows())
                                        .map(|r| result[(r, route.column)])
                                        .collect();
                                    metrics.record_latency(
                                        route.submitted_at.elapsed().as_secs_f64(),
                                    );
                                    let _ = route.reply.send(Ok(col));
                                }
                                crate::log_debug!(
                                    "master",
                                    "job {:?} done in {:.1}ms ({} groups used)",
                                    gr.id,
                                    state.dispatched_at.elapsed().as_secs_f64() * 1e3,
                                    k2
                                );
                            }
                            Err(e) => {
                                Metrics::inc(&metrics.failed);
                                for route in &state.replies {
                                    let _ = route
                                        .reply
                                        .send(Err(format!("cross-group decode failed: {e}")));
                                }
                            }
                        }
                        // Trim: keep the entry so later deliveries are
                        // recognized as late, but free the payloads.
                        let state = jobs.get_mut(&gr.id).expect("state exists");
                        state.groups.clear();
                        state.groups.shrink_to_fit();
                        state.replies.clear();
                    }
                }
            }
        })
        .expect("failed to spawn master thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::GroupResult;
    use crate::linalg::ops;
    use crate::util::rng::Rng;

    /// Drive the master with synthetic group results.
    #[test]
    fn master_decodes_at_k2th_group_and_replies() {
        let code = Arc::new(HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap());
        let mut r = Rng::new(8);
        let a = Matrix::from_fn(8, 3, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 2, |_, _| r.uniform(-1.0, 1.0));
        let expect = ops::matmul(&a, &x);
        // Build group results Ã_i·X from the code's own encode: the
        // systematic inner prefix (first k1 shards) stacks to Ã_i.
        let coded_groups = {
            let grouped = code.encode_grouped(&a).unwrap();
            (0..3)
                .map(|i| Matrix::vstack(&grouped[i][..2].to_vec()).unwrap())
                .collect::<Vec<_>>()
        };
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let h = spawn(
            Arc::clone(&code),
            vec![], // no submasters needed: we inject group results
            8,
            Arc::clone(&metrics),
            master_rx,
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        let id = JobId(9);
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id,
                    x: Arc::new(x.clone()),
                },
                replies: vec![
                    ReplyRoute {
                        reply: reply_tx.clone(),
                        column: 0,
                        submitted_at: Instant::now(),
                    },
                    ReplyRoute {
                        reply: reply_tx,
                        column: 1,
                        submitted_at: Instant::now(),
                    },
                ],
            })
            .unwrap();
        // Deliver groups 2 and 1 (parity + systematic) — k2 = 2.
        for &g in &[2usize, 1usize] {
            master_tx
                .send(MasterMsg::Group(GroupResult {
                    id,
                    group: g,
                    data: ops::matmul(&coded_groups[g], &x),
                    decode_flops: 0,
                    finished_at: Instant::now(),
                }))
                .unwrap();
        }
        let r0 = reply_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .unwrap()
            .unwrap();
        let r1 = reply_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .unwrap()
            .unwrap();
        for (i, &v) in r0.iter().enumerate() {
            assert!((v - expect[(i, 0)]).abs() < 1e-4, "col0[{i}]: {v}");
        }
        for (i, &v) in r1.iter().enumerate() {
            assert!((v - expect[(i, 1)]).abs() < 1e-4, "col1[{i}]: {v}");
        }
        // Late third group is ignored.
        master_tx
            .send(MasterMsg::Group(GroupResult {
                id,
                group: 0,
                data: ops::matmul(&coded_groups[0], &x),
                decode_flops: 0,
                finished_at: Instant::now(),
            }))
            .unwrap();
        master_tx.send(MasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 0);
    }
}
