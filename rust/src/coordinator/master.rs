//! Master thread: the job state machine at the root of Fig. 1,
//! scheme-generic.
//!
//! Broadcasts batched jobs to all submasters and runs one streaming
//! [`Decoder`] session per job ([`CodedScheme::master_decoder`]). For
//! the hierarchical scheme the session consumes decoded group results
//! (the outer code); for flat schemes the submasters are relays and the
//! session consumes raw worker products. The moment a session reports
//! `Ready`, the master finishes it, splits the batch back into
//! per-request columns, fans the replies out, and tells every submaster
//! the job is over (cancelling still-pending worker computes). Late
//! partials are discarded.
//!
//! Clients that abandon a request ([`MasterMsg::CancelRequest`]) have
//! their reply route dropped; a job nobody waits on anymore is
//! cancelled outright so it leaks neither decode work nor state.

use crate::coding::{CodedScheme, DecodeOutput, DecodeProgress, Decoder, WorkerResult};
use crate::coordinator::messages::{
    JobId, MasterMsg, ReplyRoute, RequestId, SubmasterMsg,
};
use crate::coordinator::metrics::Metrics;
use std::collections::{HashMap, HashSet};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

enum JobState {
    Active(ActiveJob),
    /// Completed, failed or cancelled — kept so late partials are
    /// recognized (payload-free, so nothing leaks).
    Done,
}

struct ActiveJob {
    /// The job's streaming decode session.
    session: Box<dyn Decoder>,
    /// Reply routing (one per batched request column).
    replies: Vec<ReplyRoute>,
    /// Dispatch time (for job-level latency).
    dispatched_at: Instant,
}

/// Deliver a finished decode to every waiting client.
fn complete_job(metrics: &Metrics, replies: &[ReplyRoute], out: &DecodeOutput) {
    Metrics::add(&metrics.decode_flops, out.flops);
    metrics.record_decode_latency(out.seconds);
    // Count completion *before* fanning out so clients never observe a
    // reply while the job still reads as in-flight.
    Metrics::inc(&metrics.completed);
    for route in replies {
        let col: Vec<f64> = (0..out.result.rows())
            .map(|r| out.result[(r, route.column)])
            .collect();
        metrics.record_latency(route.submitted_at.elapsed().as_secs_f64());
        let _ = route.reply.send(Ok(col));
    }
}

/// Deliver a decode failure to every waiting client.
fn fail_job(metrics: &Metrics, replies: &[ReplyRoute], msg: &str) {
    Metrics::inc(&metrics.failed);
    for route in replies {
        let _ = route.reply.send(Err(msg.to_string()));
    }
}

/// Spawn the master thread.
pub fn spawn(
    scheme: Arc<dyn CodedScheme>,
    submasters: Vec<mpsc::Sender<SubmasterMsg>>,
    out_rows: usize,
    metrics: Arc<Metrics>,
    rx: mpsc::Receiver<MasterMsg>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name("hiercode-master".to_string())
        .spawn(move || {
            let mut jobs: HashMap<JobId, JobState> = HashMap::new();
            // Request → job lookup for O(1) cancellation. Entries are
            // consumed by CancelRequest; like the Done entries in
            // `jobs`, the rest are kept so a cancel racing completion
            // is recognized as late instead of leaking elsewhere.
            let mut req_index: HashMap<RequestId, JobId> = HashMap::new();
            // Cancellations that arrived before their request was
            // batched into a job (bounded; see CancelSet's rationale).
            let mut cancelled_reqs: HashSet<RequestId> = HashSet::new();
            while let Ok(msg) = rx.recv() {
                match msg {
                    MasterMsg::Shutdown => {
                        for sm in &submasters {
                            let _ = sm.send(SubmasterMsg::Shutdown);
                        }
                        break;
                    }
                    MasterMsg::Batch { job, replies } => {
                        Metrics::inc(&metrics.jobs);
                        let mut replies = replies;
                        if !cancelled_reqs.is_empty() {
                            replies.retain(|r| !cancelled_reqs.remove(&r.req_id));
                        }
                        if replies.is_empty() {
                            // Every client already gave up: never dispatch.
                            Metrics::inc(&metrics.cancelled);
                            jobs.insert(job.id, JobState::Done);
                            continue;
                        }
                        for route in &replies {
                            req_index.insert(route.req_id, job.id);
                        }
                        let session = scheme.master_decoder(out_rows, job.x.cols());
                        jobs.insert(
                            job.id,
                            JobState::Active(ActiveJob {
                                session,
                                replies,
                                dispatched_at: Instant::now(),
                            }),
                        );
                        for sm in &submasters {
                            let _ = sm.send(SubmasterMsg::Job(crate::coordinator::messages::JobBroadcast {
                                id: job.id,
                                x: Arc::clone(&job.x),
                            }));
                        }
                    }
                    MasterMsg::Partial(pr) => {
                        let finished = match jobs.get_mut(&pr.id) {
                            None | Some(JobState::Done) => continue, // late delivery
                            Some(JobState::Active(state)) => {
                                let pushed = state.session.push(WorkerResult {
                                    shard: pr.shard,
                                    data: pr.data,
                                });
                                match pushed {
                                    Ok(DecodeProgress::NeedMore { .. }) => false,
                                    Ok(DecodeProgress::Ready) => {
                                        match state.session.finish() {
                                            Ok(out) => {
                                                debug_assert_eq!(
                                                    out.result.rows(),
                                                    out_rows
                                                );
                                                complete_job(
                                                    &metrics,
                                                    &state.replies,
                                                    &out,
                                                );
                                                crate::log_debug!(
                                                    "master",
                                                    "job {:?} done in {:.1}ms",
                                                    pr.id,
                                                    state
                                                        .dispatched_at
                                                        .elapsed()
                                                        .as_secs_f64()
                                                        * 1e3
                                                );
                                            }
                                            Err(e) => fail_job(
                                                &metrics,
                                                &state.replies,
                                                &format!("decode failed: {e}"),
                                            ),
                                        }
                                        true
                                    }
                                    Err(e) => {
                                        fail_job(
                                            &metrics,
                                            &state.replies,
                                            &format!("decode rejected a result: {e}"),
                                        );
                                        true
                                    }
                                }
                            }
                        };
                        if finished {
                            jobs.insert(pr.id, JobState::Done);
                            for sm in &submasters {
                                let _ = sm.send(SubmasterMsg::Finish(pr.id));
                            }
                        }
                    }
                    MasterMsg::CancelRequest(req) => {
                        match req_index.remove(&req) {
                            Some(job_id) => {
                                // O(1) lookup; a cancel racing completion
                                // finds the job Done and is a no-op.
                                let mut orphaned = false;
                                if let Some(JobState::Active(active)) =
                                    jobs.get_mut(&job_id)
                                {
                                    active.replies.retain(|r| r.req_id != req);
                                    orphaned = active.replies.is_empty();
                                }
                                if orphaned {
                                    // Nobody waits on this job anymore.
                                    Metrics::inc(&metrics.cancelled);
                                    jobs.insert(job_id, JobState::Done);
                                    for sm in &submasters {
                                        let _ =
                                            sm.send(SubmasterMsg::Finish(job_id));
                                    }
                                    crate::log_debug!(
                                        "master",
                                        "job {job_id:?} cancelled (all clients gone)"
                                    );
                                }
                            }
                            None => {
                                // Not batched yet: remember it for Batch time
                                // (bounded, like CancelSet).
                                if cancelled_reqs.len() > 4096 {
                                    cancelled_reqs.clear();
                                }
                                cancelled_reqs.insert(req);
                            }
                        }
                    }
                }
            }
        })
        .expect("failed to spawn master thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::HierarchicalCode;
    use crate::coordinator::messages::{JobBroadcast, PartialResult};
    use crate::linalg::{ops, Matrix};
    use crate::util::rng::Rng;

    /// Drive the master with synthetic group partials (hierarchical
    /// scheme: master session = outer code).
    #[test]
    fn master_decodes_at_k2th_group_and_replies() {
        let code = Arc::new(HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap());
        let mut r = Rng::new(8);
        let a = Matrix::from_fn(8, 3, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 2, |_, _| r.uniform(-1.0, 1.0));
        let expect = ops::matmul(&a, &x);
        // Build group results Ã_i·X from the code's own encode: the
        // systematic inner prefix (first k1 shards) stacks to Ã_i.
        let coded_groups = {
            let grouped = code.encode_grouped(&a).unwrap();
            (0..3)
                .map(|i| Matrix::vstack(&grouped[i][..2].to_vec()).unwrap())
                .collect::<Vec<_>>()
        };
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            Arc::clone(&scheme),
            vec![], // no submasters needed: we inject partials
            8,
            Arc::clone(&metrics),
            master_rx,
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        let id = JobId(9);
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id,
                    x: Arc::new(x.clone()),
                },
                replies: vec![
                    ReplyRoute {
                        reply: reply_tx.clone(),
                        column: 0,
                        submitted_at: Instant::now(),
                        req_id: RequestId(0),
                    },
                    ReplyRoute {
                        reply: reply_tx,
                        column: 1,
                        submitted_at: Instant::now(),
                        req_id: RequestId(1),
                    },
                ],
            })
            .unwrap();
        // Deliver groups 2 and 1 (parity + systematic) — k2 = 2.
        for &g in &[2usize, 1usize] {
            master_tx
                .send(MasterMsg::Partial(PartialResult {
                    id,
                    shard: g,
                    data: ops::matmul(&coded_groups[g], &x),
                    decode_flops: 0,
                    finished_at: Instant::now(),
                }))
                .unwrap();
        }
        let r0 = reply_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .unwrap()
            .unwrap();
        let r1 = reply_rx
            .recv_timeout(std::time::Duration::from_secs(5))
            .unwrap()
            .unwrap();
        for (i, &v) in r0.iter().enumerate() {
            assert!((v - expect[(i, 0)]).abs() < 1e-4, "col0[{i}]: {v}");
        }
        for (i, &v) in r1.iter().enumerate() {
            assert!((v - expect[(i, 1)]).abs() < 1e-4, "col1[{i}]: {v}");
        }
        // Late third group is ignored.
        master_tx
            .send(MasterMsg::Partial(PartialResult {
                id,
                shard: 0,
                data: ops::matmul(&coded_groups[0], &x),
                decode_flops: 0,
                finished_at: Instant::now(),
            }))
            .unwrap();
        master_tx.send(MasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 0);
    }

    /// Cancelling every request of a job cancels the job itself; its
    /// late partials are then discarded and nothing decodes.
    #[test]
    fn cancelled_job_never_decodes() {
        let code = Arc::new(HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap());
        let mut r = Rng::new(9);
        let a = Matrix::from_fn(8, 3, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 1, |_, _| r.uniform(-1.0, 1.0));
        let coded_groups = {
            let grouped = code.encode_grouped(&a).unwrap();
            (0..3)
                .map(|i| Matrix::vstack(&grouped[i][..2].to_vec()).unwrap())
                .collect::<Vec<_>>()
        };
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(scheme, vec![], 8, Arc::clone(&metrics), master_rx);
        let (reply_tx, reply_rx) = mpsc::channel();
        let id = JobId(1);
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id,
                    x: Arc::new(x.clone()),
                },
                replies: vec![ReplyRoute {
                    reply: reply_tx,
                    column: 0,
                    submitted_at: Instant::now(),
                    req_id: RequestId(7),
                }],
            })
            .unwrap();
        master_tx
            .send(MasterMsg::CancelRequest(RequestId(7)))
            .unwrap();
        // Enough partials to decode — but the job is already cancelled.
        for &g in &[0usize, 1] {
            master_tx
                .send(MasterMsg::Partial(PartialResult {
                    id,
                    shard: g,
                    data: ops::matmul(&coded_groups[g], &x),
                    decode_flops: 0,
                    finished_at: Instant::now(),
                }))
                .unwrap();
        }
        master_tx.send(MasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        assert!(
            reply_rx.recv().is_err(),
            "cancelled request must never get a reply"
        );
        let s = metrics.snapshot();
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.completed, 0);
        assert_eq!(s.decode_flops, 0, "no decode work for a cancelled job");
    }

    /// A cancellation arriving before the Batch drops the route at
    /// Batch time (the request was still in the batcher's buffer).
    #[test]
    fn pre_batch_cancellation_respected() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(scheme, vec![], 2, Arc::clone(&metrics), master_rx);
        master_tx
            .send(MasterMsg::CancelRequest(RequestId(3)))
            .unwrap();
        let (reply_tx, reply_rx) = mpsc::channel();
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(5),
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![ReplyRoute {
                    reply: reply_tx,
                    column: 0,
                    submitted_at: Instant::now(),
                    req_id: RequestId(3),
                }],
            })
            .unwrap();
        master_tx.send(MasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        assert!(reply_rx.recv().is_err());
        assert_eq!(metrics.snapshot().cancelled, 1);
    }
}
