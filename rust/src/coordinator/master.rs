//! Master thread: the job state machine at the root of Fig. 1,
//! scheme-generic and model-agnostic (output sizing rides on each job).
//!
//! Broadcasts batched jobs to all submasters and runs one streaming
//! [`Decoder`] session per job ([`CodedScheme::master_decoder`]). For
//! the hierarchical scheme the session consumes decoded group results
//! (the outer code); for flat schemes the submasters are relays and the
//! session consumes raw worker products. The moment a session reports
//! `Ready`, the master finishes it, splits the batch back into
//! per-request columns, completes every request's slot, and tells every
//! submaster the job is over (cancelling still-pending worker
//! computes). Late partials are discarded.
//!
//! Admission control's deadline reaches here too: routes whose deadline
//! expired while the batch sat in the master's queue are shed before
//! dispatch, so a saturated master doesn't burn worker time on requests
//! nobody is waiting for.
//!
//! Clients that abandon a request ([`MasterMsg::CancelRequest`]) have
//! their reply route dropped; a job nobody waits on anymore is
//! cancelled outright so it leaks neither decode work nor state.
//!
//! **Graceful shutdown** is a drain, not a drop: when the batcher
//! exits it sends [`MasterMsg::Drain`] behind its last batch. The
//! master then keeps serving in-flight jobs until they all complete —
//! bounded by the drain grace, after which the stragglers' routes are
//! failed — so no [`crate::coordinator::JobHandle`] ever hangs across
//! `shutdown`: every accepted request gets a terminal outcome.

use crate::coding::{CodedScheme, DecodeOutput, DecodeProgress, Decoder, WorkerResult};
use crate::coordinator::chaos::{FailureDetector, LivenessConfig};
use crate::coordinator::messages::{
    JobError, JobId, MasterMsg, ReplyRoute, RequestId, SubmasterMsg,
};
use crate::coordinator::metrics::Metrics;
use crate::sync::{Clock, DrainState};
use crate::transport::Transport;
use std::collections::{HashMap, HashSet};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

enum JobState {
    Active(ActiveJob),
    /// Completed, failed or cancelled — kept so late partials are
    /// recognized (payload-free, so nothing leaks).
    Done,
}

struct ActiveJob {
    /// The job's streaming decode session.
    session: Box<dyn Decoder>,
    /// Reply routing (one per batched request column).
    replies: Vec<ReplyRoute>,
    /// Dispatch time (for job-level latency).
    dispatched_at: Instant,
}

/// Deliver a finished decode to every waiting client.
fn complete_job(metrics: &Metrics, replies: &[ReplyRoute], out: &DecodeOutput) {
    Metrics::add(&metrics.decode_flops, out.flops);
    metrics.record_decode_latency(out.seconds);
    // Count completion *before* fanning out so clients never observe a
    // reply while the job still reads as in-flight.
    Metrics::inc(&metrics.completed);
    for route in replies {
        let col: Vec<f64> = (0..out.result.rows())
            .map(|r| out.result[(r, route.column)])
            .collect();
        // Per-request accounting keys on the winning slot write: a
        // route some earlier path already resolved (e.g. shed) must
        // not also count as completed.
        if route.slot.complete(Ok(col)) {
            metrics.record_latency(route.submitted_at.elapsed().as_secs_f64());
            Metrics::inc(&route.entry.completed);
        }
    }
}

/// Deliver a decode failure to every waiting client.
fn fail_job(metrics: &Metrics, replies: &[ReplyRoute], msg: &str) {
    Metrics::inc(&metrics.failed);
    for route in replies {
        route.slot.complete(Err(JobError::Failed(msg.to_string())));
    }
}

/// Shed one route whose admission deadline expired in the master
/// queue. Idempotent per request: the counters only move when this
/// shed actually delivered the route's terminal outcome — a request
/// the batcher (or anyone else) already resolved is never
/// double-counted, which is what kept the `shed` counter and the
/// `queue_depth` gauge honest.
fn shed_route(metrics: &Metrics, route: &ReplyRoute) {
    if route.slot.complete(Err(JobError::Deadline)) {
        Metrics::inc(&metrics.shed);
        Metrics::inc(&route.entry.shed);
    }
}

/// `Done` tombstones exist only so late partials are recognized; in a
/// long-running service they would otherwise accumulate one entry per
/// job forever. Past this bound the oldest information is expendable:
/// dropping a tombstone turns a late partial into an unknown-job drop —
/// the same outcome — so evict them all and keep only live jobs.
const DONE_JOBS_BOUND: usize = 8192;

fn gc_done_jobs(jobs: &mut HashMap<JobId, JobState>) {
    if jobs.len() > DONE_JOBS_BOUND {
        jobs.retain(|_, s| matches!(s, JobState::Active(_)));
    }
}

/// One failure-detector sweep: refresh the per-group liveness gauges
/// and, when fewer than `k2` groups remain healthy, fail every active
/// job fast with [`JobError::Insufficient`] — an undecodable job must
/// not hang until its client's deadline. Returns `true` when a drain
/// in progress settled its last job.
#[allow(clippy::too_many_arguments)]
fn liveness_sweep(
    detector: &FailureDetector,
    now_ms: u64,
    thresholds: &[usize],
    k2: usize,
    metrics: &Metrics,
    jobs: &mut HashMap<JobId, JobState>,
    req_index: &mut HashMap<RequestId, JobId>,
    drain: &mut DrainState,
    transport: &Arc<dyn Transport>,
) -> bool {
    for g in 0..thresholds.len() {
        metrics.set_group_liveness(
            g,
            detector.alive_workers(g, now_ms) as u64,
            detector.suspected_workers(g, now_ms) as u64,
        );
    }
    let healthy = detector.healthy_groups(thresholds, now_ms);
    if healthy >= k2 {
        return false;
    }
    let active: Vec<JobId> = jobs
        .iter()
        .filter(|(_, s)| matches!(s, JobState::Active(_)))
        .map(|(id, _)| *id)
        .collect();
    let mut can_exit = false;
    for id in active {
        if let Some(JobState::Active(job)) = jobs.get_mut(&id) {
            Metrics::inc(&metrics.failed);
            for route in &job.replies {
                req_index.remove(&route.req_id);
                route.slot.complete(Err(JobError::Insufficient {
                    needed: k2,
                    got: healthy,
                }));
            }
            job.replies.clear();
        }
        jobs.insert(id, JobState::Done);
        if drain.job_settled() {
            can_exit = true;
        }
        for g in 0..transport.groups() {
            transport.send(g, SubmasterMsg::Finish(id));
        }
        crate::log_debug!(
            "master",
            "job {id:?} failed fast: {healthy} healthy group(s) < k2 = {k2}"
        );
    }
    can_exit
}

/// Spawn the master thread. `drain_grace` bounds how long a shutdown
/// drain waits for in-flight jobs before failing their routes (an
/// **absolute** budget from the moment the drain begins — heartbeats
/// or other chatter must not keep resetting it). With `liveness`
/// enabled the master runs a [`FailureDetector`] over the beacon
/// streams on `clock` time, exports per-group `alive`/`suspected`
/// gauges, and fails active jobs fast once fewer than `k2` groups are
/// healthy. Errors only if the OS refuses to spawn the thread.
pub fn spawn(
    scheme: Arc<dyn CodedScheme>,
    transport: Arc<dyn Transport>,
    metrics: Arc<Metrics>,
    drain_grace: Duration,
    liveness: LivenessConfig,
    clock: Arc<dyn Clock>,
    rx: mpsc::Receiver<MasterMsg>,
) -> crate::Result<thread::JoinHandle<()>> {
    let topo = scheme.topology();
    let handle = thread::Builder::new()
        .name("hiercode-master".to_string())
        .spawn(move || {
            // The selection is process-wide and happens once; logging it
            // from the master ties every decode latency in this run to
            // the kernel set that produced it.
            crate::log_debug!(
                "master",
                "decode kernels: {}",
                crate::linalg::dispatch::active_name()
            );
            // Hot reload (`MasterMsg::Reconfigure`) swaps the scheme
            // and everything derived from it; the rollout gate
            // guarantees group count and sizes never change, so the
            // failure detector built below stays valid across swaps.
            let mut scheme = scheme;
            let mut topo = topo;
            let mut jobs: HashMap<JobId, JobState> = HashMap::new();
            // Request → job lookup for O(1) cancellation. Entries are
            // consumed by CancelRequest; like the Done entries in
            // `jobs`, the rest are kept so a cancel racing completion
            // is recognized as late instead of leaking elsewhere.
            let mut req_index: HashMap<RequestId, JobId> = HashMap::new();
            // Cancellations that arrived before their request was
            // batched into a job (bounded; see CancelSet's rationale).
            let mut cancelled_reqs: HashSet<RequestId> = HashSet::new();
            // In-flight (Active) job count + drain flag; drives the
            // drain exit (model-checked: see `tests/model_check.rs`).
            let mut drain = DrainState::new();
            // Absolute drain deadline, set when the drain begins.
            let mut drain_deadline: Option<Instant> = None;
            // A pending quiesce acknowledgement: answered the moment
            // the in-flight job count reaches zero (the batcher is
            // paused first, so zero stays zero until the rollout
            // resumes it).
            let mut quiesce: Option<mpsc::Sender<()>> = None;
            // Failure detector over the liveness beacon streams.
            let mut thresholds: Vec<usize> = topo.groups.iter().map(|g| g.k1).collect();
            let group_sizes = topo.group_sizes();
            let mut detector = FailureDetector::new(
                &group_sizes,
                u64::try_from(liveness.suspect.as_millis()).unwrap_or(u64::MAX),
                u64::try_from(liveness.dead.as_millis()).unwrap_or(u64::MAX),
                clock.now_ms(),
            );
            let mut last_sweep = Instant::now();
            loop {
                let msg = if drain.draining() {
                    // Drain mode: in-flight jobs share one absolute
                    // grace budget; then we abandon them (their routes
                    // are failed below — never left hanging). The
                    // budget must NOT reset per message: liveness
                    // beacons arrive faster than any grace, and a
                    // quiet-time drain would never fire under them.
                    let now = Instant::now();
                    let deadline = drain_deadline.unwrap_or(now);
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(m) => m,
                        Err(_) => break,
                    }
                } else if liveness.enabled {
                    // Liveness mode: wake at the heartbeat cadence to
                    // sweep the detector even when no messages flow.
                    match rx.recv_timeout(liveness.heartbeat) {
                        Ok(m) => m,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            let can_exit = liveness_sweep(
                                &detector,
                                clock.now_ms(),
                                &thresholds,
                                topo.k2,
                                &metrics,
                                &mut jobs,
                                &mut req_index,
                                &mut drain,
                                &transport,
                            );
                            last_sweep = Instant::now();
                            if can_exit {
                                break;
                            }
                            // A sweep can settle the last in-flight job
                            // (failed fast) — answer a waiting quiesce.
                            if quiesce.is_some() && drain.active() == 0 {
                                if let Some(ack) = quiesce.take() {
                                    let _ = ack.send(());
                                }
                            }
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                } else {
                    match rx.recv() {
                        Ok(m) => m,
                        Err(_) => break,
                    }
                };
                match msg {
                    MasterMsg::Heartbeat { group, worker } => {
                        detector.beat(group, worker, clock.now_ms());
                    }
                    MasterMsg::Drain => {
                        if drain.begin_drain() {
                            break;
                        }
                        drain_deadline = Some(Instant::now() + drain_grace);
                        crate::log_debug!(
                            "master",
                            "draining: {} job(s) in flight",
                            drain.active()
                        );
                    }
                    MasterMsg::Batch { job, replies } => {
                        Metrics::inc(&metrics.jobs);
                        let mut replies = replies;
                        let before = replies.len();
                        if !cancelled_reqs.is_empty() {
                            replies.retain(|r| !cancelled_reqs.remove(&r.req_id));
                        }
                        let removed_by_cancel = before - replies.len();
                        // Shed requests whose admission deadline passed
                        // while the batch queued here.
                        let now = Instant::now();
                        replies.retain(|r| {
                            if r.deadline <= now {
                                shed_route(&metrics, r);
                                false
                            } else {
                                true
                            }
                        });
                        if replies.is_empty() {
                            // Nobody is waiting: never dispatch. Only a
                            // batch emptied by *cancellation* counts as
                            // cancelled — all-shed batches are already
                            // fully accounted by the shed counter.
                            if removed_by_cancel > 0 {
                                Metrics::inc(&metrics.cancelled);
                            }
                            jobs.insert(job.id, JobState::Done);
                            gc_done_jobs(&mut jobs);
                            continue;
                        }
                        for route in &replies {
                            req_index.insert(route.req_id, job.id);
                        }
                        let session =
                            scheme.master_decoder(job.out_rows, job.x.cols());
                        jobs.insert(
                            job.id,
                            JobState::Active(ActiveJob {
                                session,
                                replies,
                                dispatched_at: Instant::now(),
                            }),
                        );
                        drain.job_dispatched();
                        for g in 0..transport.groups() {
                            transport.send(g, SubmasterMsg::Job(job.clone()));
                        }
                    }
                    MasterMsg::Partial(pr) => {
                        let finished = match jobs.get_mut(&pr.id) {
                            None | Some(JobState::Done) => {
                                // Late delivery — whether the tombstone
                                // is still around or was evicted by
                                // `gc_done_jobs` (every job id here was
                                // minted by our own batcher, so an
                                // unknown id IS a GC'd tombstone, not a
                                // foreign job). Count it either way.
                                Metrics::inc(&metrics.late_partials);
                                continue;
                            }
                            Some(JobState::Active(state)) => {
                                let pushed = state.session.push(WorkerResult {
                                    shard: pr.shard,
                                    data: pr.data,
                                });
                                match pushed {
                                    Ok(DecodeProgress::NeedMore { .. }) => false,
                                    Ok(DecodeProgress::Ready) => {
                                        match state.session.finish() {
                                            Ok(out) => {
                                                complete_job(
                                                    &metrics,
                                                    &state.replies,
                                                    &out,
                                                );
                                                crate::log_debug!(
                                                    "master",
                                                    "job {:?} done in {:.1}ms",
                                                    pr.id,
                                                    state
                                                        .dispatched_at
                                                        .elapsed()
                                                        .as_secs_f64()
                                                        * 1e3
                                                );
                                            }
                                            Err(e) => fail_job(
                                                &metrics,
                                                &state.replies,
                                                &format!("decode failed: {e}"),
                                            ),
                                        }
                                        true
                                    }
                                    Err(e) => {
                                        fail_job(
                                            &metrics,
                                            &state.replies,
                                            &format!("decode rejected a result: {e}"),
                                        );
                                        true
                                    }
                                }
                            }
                        };
                        if finished {
                            // Long-running service hygiene: release the
                            // finished job's request-index entries and
                            // keep the Done tombstone set bounded.
                            if let Some(JobState::Active(state)) = jobs.get(&pr.id) {
                                for route in &state.replies {
                                    req_index.remove(&route.req_id);
                                }
                            }
                            jobs.insert(pr.id, JobState::Done);
                            gc_done_jobs(&mut jobs);
                            let can_exit = drain.job_settled();
                            for g in 0..transport.groups() {
                                transport.send(g, SubmasterMsg::Finish(pr.id));
                            }
                            if can_exit {
                                break;
                            }
                        }
                    }
                    MasterMsg::CancelRequest(req) => {
                        match req_index.remove(&req) {
                            Some(job_id) => {
                                // O(1) lookup; a cancel racing completion
                                // finds the job Done and is a no-op.
                                let mut orphaned = false;
                                if let Some(JobState::Active(state)) =
                                    jobs.get_mut(&job_id)
                                {
                                    state.replies.retain(|r| r.req_id != req);
                                    orphaned = state.replies.is_empty();
                                }
                                if orphaned {
                                    // Nobody waits on this job anymore.
                                    Metrics::inc(&metrics.cancelled);
                                    jobs.insert(job_id, JobState::Done);
                                    gc_done_jobs(&mut jobs);
                                    let can_exit = drain.job_settled();
                                    for g in 0..transport.groups() {
                                        transport
                                            .send(g, SubmasterMsg::Finish(job_id));
                                    }
                                    crate::log_debug!(
                                        "master",
                                        "job {job_id:?} cancelled (all clients gone)"
                                    );
                                    if can_exit {
                                        break;
                                    }
                                }
                            }
                            None => {
                                // Not batched yet: remember it for Batch time
                                // (bounded, like CancelSet).
                                if cancelled_reqs.len() > 4096 {
                                    cancelled_reqs.clear();
                                }
                                cancelled_reqs.insert(req);
                            }
                        }
                    }
                    MasterMsg::Reconfigure(swap) => {
                        // Sent only while quiesced (no Active jobs), so
                        // no decode session ever spans two encodings.
                        scheme = swap.0;
                        topo = scheme.topology();
                        thresholds = topo.groups.iter().map(|g| g.k1).collect();
                        crate::log_debug!(
                            "master",
                            "reconfigured: decoding under '{}'",
                            scheme.name()
                        );
                    }
                    MasterMsg::Quiesce(ack) => {
                        if drain.active() == 0 {
                            let _ = ack.send(());
                        } else {
                            quiesce = Some(ack);
                        }
                    }
                }
                // Answer a pending quiesce the moment the last in-flight
                // job settles (every settle path falls through here).
                if quiesce.is_some() && drain.active() == 0 {
                    if let Some(ack) = quiesce.take() {
                        let _ = ack.send(());
                    }
                }
                // A steady message stream (heartbeats, partials) keeps
                // the recv from timing out, so sweep opportunistically
                // in the message path too.
                if liveness.enabled && last_sweep.elapsed() >= liveness.heartbeat {
                    let can_exit = liveness_sweep(
                        &detector,
                        clock.now_ms(),
                        &thresholds,
                        topo.k2,
                        &metrics,
                        &mut jobs,
                        &mut req_index,
                        &mut drain,
                        &transport,
                    );
                    last_sweep = Instant::now();
                    if can_exit {
                        break;
                    }
                }
            }
            // Exit invariant: no accepted request may be left pending.
            // Jobs still Active here outlived the drain grace (e.g.
            // dead links made them undecodable) — fail their routes.
            for state in jobs.values_mut() {
                if let JobState::Active(job) = state {
                    Metrics::inc(&metrics.failed);
                    for route in &job.replies {
                        route.slot.complete(Err(JobError::Shutdown));
                    }
                    job.replies.clear();
                }
            }
            for g in 0..transport.groups() {
                transport.send(g, SubmasterMsg::Shutdown);
            }
        })?;
    Ok(handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::HierarchicalCode;
    use crate::coordinator::messages::{
        CompletionSlot, JobBroadcast, ModelEntry, ModelId, PartialResult,
    };
    use crate::linalg::{ops, Matrix};
    use crate::util::rng::Rng;

    fn test_entry(d: usize, m: usize) -> Arc<ModelEntry> {
        Arc::new(ModelEntry::new(ModelId(0), "default", d, m, 64, None))
    }

    /// A transport with no downstream links: these tests inject
    /// partials directly, so broadcasts go nowhere.
    fn no_transport() -> Arc<dyn Transport> {
        Arc::new(crate::transport::memory::MemoryTransport::new(vec![]))
    }

    fn far_deadline() -> Instant {
        Instant::now() + Duration::from_secs(60)
    }

    fn route(
        entry: &Arc<ModelEntry>,
        slot: &Arc<CompletionSlot>,
        column: usize,
        req: u64,
    ) -> ReplyRoute {
        ReplyRoute {
            entry: Arc::clone(entry),
            slot: Arc::clone(slot),
            column,
            submitted_at: Instant::now(),
            deadline: far_deadline(),
            req_id: RequestId(req),
        }
    }

    /// Drive the master with synthetic group partials (hierarchical
    /// scheme: master session = outer code).
    #[test]
    fn master_decodes_at_k2th_group_and_replies() {
        let code = Arc::new(HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap());
        let mut r = Rng::new(8);
        let a = Matrix::from_fn(8, 3, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 2, |_, _| r.uniform(-1.0, 1.0));
        let expect = ops::matmul(&a, &x);
        // Build group results Ã_i·X from the code's own encode: the
        // systematic inner prefix (first k1 shards) stacks to Ã_i.
        let coded_groups = {
            let grouped = code.encode_grouped(&a).unwrap();
            (0..3)
                .map(|i| Matrix::vstack(&grouped[i][..2].to_vec()).unwrap())
                .collect::<Vec<_>>()
        };
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            Arc::clone(&scheme),
            no_transport(), // no submasters needed: we inject partials
            Arc::clone(&metrics),
            Duration::from_secs(5),
            LivenessConfig::disabled(),
            Arc::new(crate::sync::WallClock::new()),
            master_rx,
        )
        .expect("spawn master");
        let entry = test_entry(3, 8);
        let slot0 = Arc::new(CompletionSlot::new());
        let slot1 = Arc::new(CompletionSlot::new());
        let id = JobId(9);
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id,
                    model: entry.id,
                    out_rows: 8,
                    x: Arc::new(x.clone()),
                },
                replies: vec![
                    route(&entry, &slot0, 0, 0),
                    route(&entry, &slot1, 1, 1),
                ],
            })
            .unwrap();
        // Deliver groups 2 and 1 (parity + systematic) — k2 = 2.
        for &g in &[2usize, 1usize] {
            master_tx
                .send(MasterMsg::Partial(PartialResult {
                    id,
                    shard: g,
                    decoded: true,
                    data: ops::matmul(&coded_groups[g], &x),
                    decode_flops: 0,
                    finished_at: Instant::now(),
                }))
                .unwrap();
        }
        let r0 = slot0.wait().unwrap();
        let r1 = slot1.wait().unwrap();
        for (i, &v) in r0.iter().enumerate() {
            assert!((v - expect[(i, 0)]).abs() < 1e-4, "col0[{i}]: {v}");
        }
        for (i, &v) in r1.iter().enumerate() {
            assert!((v - expect[(i, 1)]).abs() < 1e-4, "col1[{i}]: {v}");
        }
        // Late third group is ignored.
        master_tx
            .send(MasterMsg::Partial(PartialResult {
                id,
                shard: 0,
                data: ops::matmul(&coded_groups[0], &x),
                decoded: true,
                decode_flops: 0,
                finished_at: Instant::now(),
            }))
            .unwrap();
        master_tx.send(MasterMsg::Drain).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.failed, 0);
        use std::sync::atomic::Ordering;
        assert_eq!(entry.completed.load(Ordering::Relaxed), 2);
    }

    /// Cancelling every request of a job cancels the job itself; its
    /// late partials are then discarded and nothing decodes.
    #[test]
    fn cancelled_job_never_decodes() {
        let code = Arc::new(HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap());
        let mut r = Rng::new(9);
        let a = Matrix::from_fn(8, 3, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 1, |_, _| r.uniform(-1.0, 1.0));
        let coded_groups = {
            let grouped = code.encode_grouped(&a).unwrap();
            (0..3)
                .map(|i| Matrix::vstack(&grouped[i][..2].to_vec()).unwrap())
                .collect::<Vec<_>>()
        };
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            scheme,
            no_transport(),
            Arc::clone(&metrics),
            Duration::from_secs(5),
            LivenessConfig::disabled(),
            Arc::new(crate::sync::WallClock::new()),
            master_rx,
        )
        .expect("spawn master");
        let entry = test_entry(3, 8);
        let slot = Arc::new(CompletionSlot::new());
        let id = JobId(1);
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id,
                    model: entry.id,
                    out_rows: 8,
                    x: Arc::new(x.clone()),
                },
                replies: vec![route(&entry, &slot, 0, 7)],
            })
            .unwrap();
        master_tx
            .send(MasterMsg::CancelRequest(RequestId(7)))
            .unwrap();
        // Enough partials to decode — but the job is already cancelled.
        for &g in &[0usize, 1] {
            master_tx
                .send(MasterMsg::Partial(PartialResult {
                    id,
                    shard: g,
                    decoded: true,
                    data: ops::matmul(&coded_groups[g], &x),
                    decode_flops: 0,
                    finished_at: Instant::now(),
                }))
                .unwrap();
        }
        master_tx.send(MasterMsg::Drain).unwrap();
        h.join().unwrap();
        assert!(
            slot.try_take().is_none(),
            "cancelled request must never get a reply"
        );
        let s = metrics.snapshot();
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.completed, 0);
        assert_eq!(s.decode_flops, 0, "no decode work for a cancelled job");
    }

    /// A cancellation arriving before the Batch drops the route at
    /// Batch time (the request was still in the batcher's buffer).
    #[test]
    fn pre_batch_cancellation_respected() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            scheme,
            no_transport(),
            Arc::clone(&metrics),
            Duration::from_secs(5),
            LivenessConfig::disabled(),
            Arc::new(crate::sync::WallClock::new()),
            master_rx,
        )
        .expect("spawn master");
        master_tx
            .send(MasterMsg::CancelRequest(RequestId(3)))
            .unwrap();
        let entry = test_entry(1, 2);
        let slot = Arc::new(CompletionSlot::new());
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(5),
                    model: entry.id,
                    out_rows: 2,
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![route(&entry, &slot, 0, 3)],
            })
            .unwrap();
        master_tx.send(MasterMsg::Drain).unwrap();
        h.join().unwrap();
        assert!(slot.try_take().is_none());
        assert_eq!(metrics.snapshot().cancelled, 1);
    }

    /// Routes whose admission deadline expired in the master's queue
    /// are shed before dispatch — counted exactly once.
    #[test]
    fn expired_routes_shed_at_batch_receipt() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            scheme,
            no_transport(),
            Arc::clone(&metrics),
            Duration::from_secs(5),
            LivenessConfig::disabled(),
            Arc::new(crate::sync::WallClock::new()),
            master_rx,
        )
        .expect("spawn master");
        let entry = test_entry(1, 2);
        let slot = Arc::new(CompletionSlot::new());
        let mut expired = route(&entry, &slot, 0, 4);
        expired.deadline = Instant::now() - Duration::from_millis(1);
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(6),
                    model: entry.id,
                    out_rows: 2,
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![expired],
            })
            .unwrap();
        master_tx.send(MasterMsg::Drain).unwrap();
        h.join().unwrap();
        assert_eq!(slot.wait(), Err(JobError::Deadline));
        let s = metrics.snapshot();
        assert_eq!(s.shed, 1);
        assert_eq!(s.completed, 0);
        use std::sync::atomic::Ordering;
        assert_eq!(entry.shed.load(Ordering::Relaxed), 1);
    }

    /// Satellite regression: shedding is idempotent per request. A
    /// route whose slot was already resolved with `Deadline` (the
    /// batcher shed it) arriving expired at Batch receipt must NOT
    /// increment the shed counters a second time — double-shed was the
    /// path to an inflated `shed` count and, one unpaired release
    /// later, an underflowed `queue_depth` gauge.
    #[test]
    fn already_shed_route_is_not_shed_again_at_batch_receipt() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            scheme,
            no_transport(),
            Arc::clone(&metrics),
            Duration::from_secs(5),
            LivenessConfig::disabled(),
            Arc::new(crate::sync::WallClock::new()),
            master_rx,
        )
        .expect("spawn master");
        let entry = test_entry(1, 2);
        let slot = Arc::new(CompletionSlot::new());
        // The batcher's shed already resolved this request…
        assert!(slot.complete(Err(JobError::Deadline)));
        // …but (bug scenario) its route still rides a Batch to the
        // master, expired.
        let mut expired = route(&entry, &slot, 0, 11);
        expired.deadline = Instant::now() - Duration::from_millis(1);
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(8),
                    model: entry.id,
                    out_rows: 2,
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![expired],
            })
            .unwrap();
        master_tx.send(MasterMsg::Drain).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.shed, 0, "the master's shed must lose the write and not count");
        use std::sync::atomic::Ordering;
        assert_eq!(entry.shed.load(Ordering::Relaxed), 0);
        assert_eq!(slot.wait(), Err(JobError::Deadline));
    }

    /// A drain with an undecodable job in flight fails the job's routes
    /// after the grace period instead of hanging.
    #[test]
    fn drain_grace_fails_stuck_jobs_instead_of_hanging() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            scheme,
            no_transport(),
            Arc::clone(&metrics),
            Duration::from_millis(50), // short grace
            LivenessConfig::disabled(),
            Arc::new(crate::sync::WallClock::new()),
            master_rx,
        )
        .expect("spawn master");
        let entry = test_entry(1, 2);
        let slot = Arc::new(CompletionSlot::new());
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(1),
                    model: entry.id,
                    out_rows: 2,
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![route(&entry, &slot, 0, 0)],
            })
            .unwrap();
        // No partials will ever arrive; drain must still terminate.
        master_tx.send(MasterMsg::Drain).unwrap();
        h.join().unwrap();
        assert_eq!(slot.wait(), Err(JobError::Shutdown));
        assert_eq!(metrics.snapshot().failed, 1);
    }

    /// Drain vs. crash race regression: liveness heartbeats arrive
    /// faster than the drain grace. A per-message `recv_timeout` would
    /// reset its quiet-time budget on every beacon and never expire;
    /// the deadline must be absolute from the moment the drain begins.
    #[test]
    fn drain_deadline_is_absolute_under_heartbeat_chatter() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let h = spawn(
            scheme,
            no_transport(),
            Arc::clone(&metrics),
            Duration::from_millis(50), // short grace
            // Long detector timeouts: beacons flow, nothing is marked.
            LivenessConfig::new(
                Duration::from_millis(5),
                Duration::from_secs(60),
                Duration::from_secs(120),
            ),
            Arc::new(crate::sync::WallClock::new()),
            master_rx,
        )
        .expect("spawn master");
        let entry = test_entry(1, 2);
        let slot = Arc::new(CompletionSlot::new());
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(1),
                    model: entry.id,
                    out_rows: 2,
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![route(&entry, &slot, 0, 0)],
            })
            .unwrap();
        master_tx.send(MasterMsg::Drain).unwrap();
        // Chatter: a beacon every ~2ms, far below the 50ms grace. The
        // stuck job means only the grace deadline can end the drain.
        let started = Instant::now();
        while started.elapsed() < Duration::from_secs(5) {
            let alive = master_tx
                .send(MasterMsg::Heartbeat {
                    group: 0,
                    worker: Some(0),
                })
                .is_ok();
            if !alive {
                break; // master exited and dropped its receiver
            }
            thread::sleep(Duration::from_millis(2));
        }
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "drain never expired under heartbeat chatter"
        );
        h.join().unwrap();
        assert_eq!(slot.wait(), Err(JobError::Shutdown));
    }

    /// With every beacon stream silent past `dead`, the sweep fails
    /// active jobs fast with `Insufficient` instead of letting them
    /// hang to their deadline. Time is mock-driven: no detector sleeps.
    #[test]
    fn liveness_sweep_fails_active_jobs_when_below_k2() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 2).unwrap());
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let clock = Arc::new(crate::sync::MockClock::new());
        let h = spawn(
            scheme,
            no_transport(),
            Arc::clone(&metrics),
            Duration::from_secs(5),
            LivenessConfig::new(
                Duration::from_millis(2),
                Duration::from_millis(10),
                Duration::from_millis(20),
            ),
            Arc::clone(&clock) as Arc<dyn crate::sync::Clock>,
            master_rx,
        )
        .expect("spawn master");
        let entry = test_entry(1, 2);
        let slot = Arc::new(CompletionSlot::new());
        master_tx
            .send(MasterMsg::Batch {
                job: JobBroadcast {
                    id: JobId(3),
                    model: entry.id,
                    out_rows: 2,
                    x: Arc::new(Matrix::identity(1)),
                },
                replies: vec![route(&entry, &slot, 0, 0)],
            })
            .unwrap();
        // Silence every beacon stream well past the dead threshold.
        clock.set(1_000);
        assert_eq!(
            slot.wait(),
            Err(JobError::Insufficient { needed: 2, got: 0 })
        );
        assert_eq!(metrics.snapshot().failed, 1);
        let snap = metrics.snapshot();
        for g in &snap.per_group {
            assert_eq!(g.alive_workers, Some(0));
        }
        master_tx.send(MasterMsg::Drain).unwrap();
        h.join().unwrap();
    }
}
