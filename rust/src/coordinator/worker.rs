//! Worker thread: `w(i, j)` of Fig. 1.
//!
//! Each worker owns one coded shard **per registered model**, installed
//! by [`WorkerCmd::Load`] at registration time (channel FIFO guarantees
//! a model's shard precedes any job that multiplies it). On a job
//! broadcast it (optionally) sleeps a straggler delay drawn from the
//! configured model — emulating the paper's `Exp(µ1)` completion times
//! on a single machine — computes `Â_{i,j}·X` through its backend (PJRT
//! artifact or native GEMM), and uploads the product to its submaster.

use crate::coordinator::backend::{ComputeBackend, WorkerShard};
use crate::coordinator::messages::{
    CancelSet, ModelId, SubmasterMsg, WorkerCmd, WorkerDone,
};
use crate::sim::straggler::StragglerModel;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Straggler-injection settings for one worker.
#[derive(Clone)]
pub struct WorkerDelay {
    /// Delay distribution (the paper's `Exp(µ1)`).
    pub model: StragglerModel,
    /// Wall-clock seconds per model time unit.
    pub scale: f64,
    /// Master switch.
    pub enabled: bool,
}

/// Spawn worker `w(group, index)`.
#[allow(clippy::too_many_arguments)]
pub fn spawn(
    group: usize,
    index: usize,
    backend: ComputeBackend,
    delay: WorkerDelay,
    dead: bool,
    cancel: std::sync::Arc<CancelSet>,
    mut rng: Rng,
    rx: mpsc::Receiver<WorkerCmd>,
    submaster: mpsc::Sender<SubmasterMsg>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("hiercode-w{group}.{index}"))
        .spawn(move || {
            let mut shards: HashMap<ModelId, WorkerShard> = HashMap::new();
            while let Ok(cmd) = rx.recv() {
                match cmd {
                    WorkerCmd::Shutdown => break,
                    WorkerCmd::Load { model, shard } => {
                        shards.insert(model, *shard);
                    }
                    WorkerCmd::Compute(job) => {
                        if dead {
                            // Fault injection: silently drop the job.
                            continue;
                        }
                        // §Perf: skip jobs the group already decoded.
                        if cancel.is_cancelled(job.id) {
                            continue;
                        }
                        let Some(shard) = shards.get(&job.model) else {
                            // Registration bug: behave like a straggler
                            // (the code absorbs missing products).
                            crate::log_error!(
                                "worker",
                                "w({group},{index}) has no shard for model {:?} \
                                 (job {:?})",
                                job.model,
                                job.id
                            );
                            continue;
                        };
                        if delay.enabled {
                            let d = delay.model.sample(&mut rng) * delay.scale;
                            if d > 0.0 {
                                thread::sleep(Duration::from_secs_f64(d));
                            }
                        }
                        // Re-check after the straggle sleep: the k1-th
                        // product may have landed while we slept.
                        if cancel.is_cancelled(job.id) {
                            continue;
                        }
                        match backend.shard_product(shard, &job.x) {
                            Ok(data) => {
                                let _ = submaster.send(SubmasterMsg::Done(WorkerDone {
                                    id: job.id,
                                    index,
                                    data,
                                }));
                            }
                            Err(e) => {
                                crate::log_error!(
                                    "worker",
                                    "w({group},{index}) job {:?} failed: {e}",
                                    job.id
                                );
                                // A failed worker behaves like a straggler:
                                // the code absorbs it.
                            }
                        }
                    }
                }
            }
        })
        .expect("failed to spawn worker thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{JobBroadcast, JobId};
    use crate::linalg::Matrix;
    use std::sync::Arc;

    fn no_delay() -> WorkerDelay {
        WorkerDelay {
            model: StragglerModel::Deterministic { value: 0.0 },
            scale: 0.0,
            enabled: false,
        }
    }

    fn load(model: ModelId, shard: &Matrix) -> WorkerCmd {
        WorkerCmd::Load {
            model,
            shard: Box::new(WorkerShard::new(shard).unwrap()),
        }
    }

    #[test]
    fn worker_computes_and_uploads() {
        let shard_m = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let h = spawn(
            1,
            3,
            ComputeBackend::Native,
            no_delay(),
            false,
            std::sync::Arc::new(CancelSet::new()),
            Rng::new(1),
            cmd_rx,
            sub_tx,
        );
        cmd_tx.send(load(ModelId(0), &shard_m)).unwrap();
        let x = Arc::new(Matrix::from_rows(&[&[1.0], &[1.0]]));
        cmd_tx
            .send(WorkerCmd::Compute(JobBroadcast {
                id: JobId(7),
                model: ModelId(0),
                out_rows: 2,
                x,
            }))
            .unwrap();
        let msg = sub_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        match msg {
            SubmasterMsg::Done(done) => {
                assert_eq!(done.id, JobId(7));
                assert_eq!(done.index, 3);
                assert_eq!(done.data.data(), &[1.0, 2.0]);
            }
            other => panic!("unexpected message {other:?}"),
        }
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn worker_serves_multiple_models_by_id() {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let h = spawn(
            0,
            0,
            ComputeBackend::Native,
            no_delay(),
            false,
            std::sync::Arc::new(CancelSet::new()),
            Rng::new(3),
            cmd_rx,
            sub_tx,
        );
        // Two models with distinguishable shards.
        cmd_tx
            .send(load(ModelId(0), &Matrix::from_rows(&[&[1.0]])))
            .unwrap();
        cmd_tx
            .send(load(ModelId(1), &Matrix::from_rows(&[&[10.0]])))
            .unwrap();
        let x = Arc::new(Matrix::from_rows(&[&[2.0]]));
        for (model, expect) in [(ModelId(1), 20.0), (ModelId(0), 2.0)] {
            cmd_tx
                .send(WorkerCmd::Compute(JobBroadcast {
                    id: JobId(model.0 as u64),
                    model,
                    out_rows: 1,
                    x: Arc::clone(&x),
                }))
                .unwrap();
            let msg = sub_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match msg {
                SubmasterMsg::Done(done) => {
                    assert_eq!(done.data.data(), &[expect], "model {model:?}");
                }
                other => panic!("unexpected message {other:?}"),
            }
        }
        // A job for an unregistered model is absorbed like a straggler.
        cmd_tx
            .send(WorkerCmd::Compute(JobBroadcast {
                id: JobId(9),
                model: ModelId(9),
                out_rows: 1,
                x,
            }))
            .unwrap();
        assert!(sub_rx.recv_timeout(Duration::from_millis(200)).is_err());
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn dead_worker_stays_silent() {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let h = spawn(
            0,
            0,
            ComputeBackend::Native,
            no_delay(),
            true, // dead
            std::sync::Arc::new(CancelSet::new()),
            Rng::new(2),
            cmd_rx,
            sub_tx,
        );
        cmd_tx.send(load(ModelId(0), &Matrix::identity(2))).unwrap();
        let x = Arc::new(Matrix::identity(2));
        cmd_tx
            .send(WorkerCmd::Compute(JobBroadcast {
                id: JobId(1),
                model: ModelId(0),
                out_rows: 2,
                x,
            }))
            .unwrap();
        assert!(sub_rx.recv_timeout(Duration::from_millis(200)).is_err());
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }
}
