//! Worker thread: `w(i, j)` of Fig. 1.
//!
//! Each worker owns one coded shard **per registered model**, installed
//! by [`WorkerCmd::Load`] at registration time (channel FIFO guarantees
//! a model's shard precedes any job that multiplies it). On a job
//! broadcast it (optionally) sleeps a straggler delay drawn from the
//! configured model — emulating the paper's `Exp(µ1)` completion times
//! on a single machine — computes `Â_{i,j}·X` through its backend (PJRT
//! artifact or native GEMM), and uploads the product to its submaster.
//!
//! # Partial-work mode
//!
//! With `subtasks = r > 1` the shard is split into `r` coded sub-shards
//! at [`WorkerCmd::Load`] time and the job runs as `r` **sequential**
//! sub-tasks: per sub-task one straggler delay of `sample/r` (the same
//! total expected work), one sub-shard product, one [`WorkerDone`]
//! uploaded immediately — so a straggling worker still streams the
//! sub-results it finished before the group decoded. Cancellation is
//! re-checked between sub-tasks: the moment the group reaches `k1·r`
//! sub-results the remaining sub-tasks are skipped.

use crate::coordinator::backend::{ComputeBackend, WorkerShard};
use crate::coordinator::fault::FaultState;
use crate::coordinator::messages::{
    CancelSet, ModelId, SubmasterMsg, WorkerCmd, WorkerDone,
};
use crate::linalg::Matrix;
use crate::sim::straggler::StragglerModel;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Straggler-injection settings for one worker.
#[derive(Clone)]
pub struct WorkerDelay {
    /// Delay distribution (the paper's `Exp(µ1)`).
    pub model: StragglerModel,
    /// Wall-clock seconds per model time unit.
    pub scale: f64,
    /// Master switch.
    pub enabled: bool,
}

/// Split a worker's shard into its `r` coded sub-shards (rows
/// `[s·b, (s+1)·b)` = sub-task `s`). The f64 data is already
/// f32-narrowed, so the re-narrowing in [`WorkerShard::new`] is the
/// identity.
fn split_shard(shard: &Matrix, r: usize) -> crate::Result<Vec<WorkerShard>> {
    shard.split_rows(r)?.iter().map(WorkerShard::new).collect()
}

/// Everything needed to spawn worker `w(group, index)` — bundled so
/// the cluster supervisor can retain it and respawn the worker on a
/// chaos restart event with the exact same wiring.
#[derive(Clone)]
pub struct WorkerCtx {
    /// Group index `i`.
    pub group: usize,
    /// In-group worker index `j`.
    pub index: usize,
    /// Compute backend (PJRT artifact or native GEMM).
    pub backend: ComputeBackend,
    /// Straggler-injection settings.
    pub delay: WorkerDelay,
    /// The group's partial-work `r` (1 = all-or-nothing tasks).
    pub subtasks: usize,
    /// Group-local cancellation registry.
    pub cancel: Arc<CancelSet>,
    /// Live fault switchboard: the worker consults its dead flag
    /// before computing or heartbeating.
    pub faults: Arc<FaultState>,
    /// Heartbeat cadence; `None` disables liveness beacons (the
    /// pre-liveness quiet-channel behavior, used by unit tests).
    pub heartbeat: Option<Duration>,
    /// Upstream channel to the group's submaster.
    pub submaster: mpsc::Sender<SubmasterMsg>,
}

/// Spawn worker `w(group, index)`. Errors only if the OS refuses to
/// spawn the thread.
pub fn spawn(
    ctx: WorkerCtx,
    mut rng: Rng,
    rx: mpsc::Receiver<WorkerCmd>,
) -> crate::Result<thread::JoinHandle<()>> {
    let WorkerCtx {
        group,
        index,
        backend,
        delay,
        subtasks,
        cancel,
        faults,
        heartbeat,
        submaster,
    } = ctx;
    let handle = thread::Builder::new()
        .name(format!("hiercode-w{group}.{index}"))
        .spawn(move || {
            // Per model: the worker's sub-shards, in sub-task order
            // (a single entry — the whole shard — when r = 1).
            let mut shards: HashMap<ModelId, Vec<WorkerShard>> = HashMap::new();
            let r = subtasks.max(1);
            // Announce liveness immediately: a respawned worker must
            // flip the failure detector back to Alive without waiting
            // a full cadence.
            if heartbeat.is_some() && !faults.worker_dead(group, index) {
                let _ = submaster.send(SubmasterMsg::Heartbeat(index));
            }
            let mut last_beat = Instant::now();
            loop {
                let cmd = match heartbeat {
                    Some(period) => match rx.recv_timeout(period) {
                        Ok(c) => c,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if !faults.worker_dead(group, index) {
                                let _ = submaster.send(SubmasterMsg::Heartbeat(index));
                            }
                            last_beat = Instant::now();
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    },
                    None => match rx.recv() {
                        Ok(c) => c,
                        Err(_) => break,
                    },
                };
                match cmd {
                    WorkerCmd::Shutdown => break,
                    WorkerCmd::Load { model, shard } => {
                        if r == 1 {
                            shards.insert(model, vec![*shard]);
                            continue;
                        }
                        // Partial-work: pre-split into the r sub-shards
                        // once, at load time.
                        match split_shard(&shard.f64, r) {
                            Ok(parts) => {
                                shards.insert(model, parts);
                            }
                            Err(e) => {
                                crate::log_error!(
                                    "worker",
                                    "w({group},{index}) cannot split model {:?} \
                                     into {r} sub-shards: {e}",
                                    model
                                );
                            }
                        }
                    }
                    WorkerCmd::Compute(job) => {
                        if faults.worker_dead(group, index) {
                            // Fault injection: silently drop the job.
                            continue;
                        }
                        // §Perf: skip jobs the group already decoded.
                        if cancel.is_cancelled(job.id) {
                            continue;
                        }
                        let Some(parts) = shards.get(&job.model) else {
                            // Registration bug: behave like a straggler
                            // (the code absorbs missing products).
                            crate::log_error!(
                                "worker",
                                "w({group},{index}) has no shard for model {:?} \
                                 (job {:?})",
                                job.model,
                                job.id
                            );
                            continue;
                        };
                        // Sequential (sub-)tasks: one delay + product +
                        // upload per sub-task. With r = 1 this is the
                        // exact pre-partial sequence (one sample, one
                        // product, one upload).
                        for (s, part) in parts.iter().enumerate() {
                            if s > 0 && cancel.is_cancelled(job.id) {
                                break; // group decoded: skip the tail
                            }
                            if delay.enabled {
                                let scale = delay.scale / parts.len() as f64;
                                let d = delay.model.sample(&mut rng) * scale;
                                if d > 0.0 {
                                    thread::sleep(Duration::from_secs_f64(d));
                                }
                            }
                            // Re-check after the straggle sleep: the
                            // decode threshold may have been reached
                            // while we slept.
                            if cancel.is_cancelled(job.id) {
                                break;
                            }
                            match backend.shard_product(part, &job.x) {
                                Ok(data) => {
                                    let _ = submaster.send(SubmasterMsg::Done(WorkerDone {
                                        id: job.id,
                                        index,
                                        subtask: s,
                                        data,
                                    }));
                                }
                                Err(e) => {
                                    crate::log_error!(
                                        "worker",
                                        "w({group},{index}) job {:?} sub-task {s} \
                                         failed: {e}",
                                        job.id
                                    );
                                    // A failed worker behaves like a
                                    // straggler: the code absorbs it.
                                    break;
                                }
                            }
                        }
                    }
                }
                // A busy worker never hits the recv timeout, so also
                // beat after handling work once the cadence elapsed.
                if let Some(period) = heartbeat {
                    if last_beat.elapsed() >= period {
                        if !faults.worker_dead(group, index) {
                            let _ = submaster.send(SubmasterMsg::Heartbeat(index));
                        }
                        last_beat = Instant::now();
                    }
                }
            }
        })?;
    Ok(handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{JobBroadcast, JobId};
    use crate::linalg::Matrix;
    use std::sync::Arc;

    fn no_delay() -> WorkerDelay {
        WorkerDelay {
            model: StragglerModel::Deterministic { value: 0.0 },
            scale: 0.0,
            enabled: false,
        }
    }

    /// Test wiring for one worker: quiet channels (no heartbeat), a
    /// fresh fault switchboard with this worker's dead flag as given.
    fn test_ctx(
        group: usize,
        index: usize,
        subtasks: usize,
        dead: bool,
        submaster: mpsc::Sender<SubmasterMsg>,
    ) -> WorkerCtx {
        let faults = Arc::new(FaultState::new(&vec![index + 1; group + 1]));
        faults.set_worker_dead(group, index, dead);
        WorkerCtx {
            group,
            index,
            backend: ComputeBackend::Native,
            delay: no_delay(),
            subtasks,
            cancel: Arc::new(CancelSet::new()),
            faults,
            heartbeat: None,
            submaster,
        }
    }

    fn load(model: ModelId, shard: &Matrix) -> WorkerCmd {
        WorkerCmd::Load {
            model,
            shard: Box::new(WorkerShard::new(shard).unwrap()),
        }
    }

    #[test]
    fn worker_computes_and_uploads() {
        let shard_m = Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let h = spawn(test_ctx(1, 3, 1, false, sub_tx), Rng::new(1), cmd_rx)
            .expect("spawn worker");
        cmd_tx.send(load(ModelId(0), &shard_m)).unwrap();
        let x = Arc::new(Matrix::from_rows(&[&[1.0], &[1.0]]));
        cmd_tx
            .send(WorkerCmd::Compute(JobBroadcast {
                id: JobId(7),
                model: ModelId(0),
                out_rows: 2,
                x,
            }))
            .unwrap();
        let msg = sub_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        match msg {
            SubmasterMsg::Done(done) => {
                assert_eq!(done.id, JobId(7));
                assert_eq!(done.index, 3);
                assert_eq!(done.subtask, 0, "all-or-nothing tasks are sub-task 0");
                assert_eq!(done.data.data(), &[1.0, 2.0]);
            }
            other => panic!("unexpected message {other:?}"),
        }
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn partial_worker_streams_one_result_per_subtask() {
        // r = 4 over an 8-row shard: the worker streams sub-results
        // 0..4 in order, each 2 rows, stacking to the full product.
        let mut rng = Rng::new(9);
        let shard_m = Matrix::from_fn(8, 3, |_, _| rng.uniform(-1.0, 1.0));
        let x = Arc::new(Matrix::from_fn(3, 2, |_, _| rng.uniform(-1.0, 1.0)));
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let h = spawn(test_ctx(0, 1, 4, false, sub_tx), Rng::new(4), cmd_rx)
            .expect("spawn worker");
        cmd_tx.send(load(ModelId(0), &shard_m)).unwrap();
        cmd_tx
            .send(WorkerCmd::Compute(JobBroadcast {
                id: JobId(5),
                model: ModelId(0),
                out_rows: 8,
                x: Arc::clone(&x),
            }))
            .unwrap();
        let mut chunks = Vec::new();
        for s in 0..4 {
            let msg = sub_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match msg {
                SubmasterMsg::Done(done) => {
                    assert_eq!(done.id, JobId(5));
                    assert_eq!(done.index, 1);
                    assert_eq!(done.subtask, s, "sub-tasks stream in order");
                    assert_eq!(done.data.shape(), (2, 2));
                    chunks.push(done.data);
                }
                other => panic!("unexpected message {other:?}"),
            }
        }
        // No fifth message: the job is done.
        assert!(sub_rx.recv_timeout(Duration::from_millis(100)).is_err());
        let stacked = Matrix::vstack(&chunks).unwrap();
        let expect = crate::linalg::ops::matmul(&shard_m, &x);
        // f32-narrowed shard: agree to f32 rounding.
        assert!(stacked.max_abs_diff(&expect) < 1e-5);
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn worker_serves_multiple_models_by_id() {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let h = spawn(test_ctx(0, 0, 1, false, sub_tx), Rng::new(3), cmd_rx)
            .expect("spawn worker");
        // Two models with distinguishable shards.
        cmd_tx
            .send(load(ModelId(0), &Matrix::from_rows(&[&[1.0]])))
            .unwrap();
        cmd_tx
            .send(load(ModelId(1), &Matrix::from_rows(&[&[10.0]])))
            .unwrap();
        let x = Arc::new(Matrix::from_rows(&[&[2.0]]));
        for (model, expect) in [(ModelId(1), 20.0), (ModelId(0), 2.0)] {
            cmd_tx
                .send(WorkerCmd::Compute(JobBroadcast {
                    id: JobId(model.0 as u64),
                    model,
                    out_rows: 1,
                    x: Arc::clone(&x),
                }))
                .unwrap();
            let msg = sub_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            match msg {
                SubmasterMsg::Done(done) => {
                    assert_eq!(done.data.data(), &[expect], "model {model:?}");
                }
                other => panic!("unexpected message {other:?}"),
            }
        }
        // A job for an unregistered model is absorbed like a straggler.
        cmd_tx
            .send(WorkerCmd::Compute(JobBroadcast {
                id: JobId(9),
                model: ModelId(9),
                out_rows: 1,
                x,
            }))
            .unwrap();
        assert!(sub_rx.recv_timeout(Duration::from_millis(200)).is_err());
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn dead_worker_stays_silent() {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let h = spawn(test_ctx(0, 0, 1, true, sub_tx), Rng::new(2), cmd_rx)
            .expect("spawn worker");
        cmd_tx.send(load(ModelId(0), &Matrix::identity(2))).unwrap();
        let x = Arc::new(Matrix::identity(2));
        cmd_tx
            .send(WorkerCmd::Compute(JobBroadcast {
                id: JobId(1),
                model: ModelId(0),
                out_rows: 2,
                x,
            }))
            .unwrap();
        assert!(sub_rx.recv_timeout(Duration::from_millis(200)).is_err());
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn heartbeats_flow_and_dynamic_death_silences_them() {
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let (sub_tx, sub_rx) = mpsc::channel();
        let mut ctx = test_ctx(0, 2, 1, false, sub_tx);
        ctx.heartbeat = Some(Duration::from_millis(5));
        let faults = Arc::clone(&ctx.faults);
        let h = spawn(ctx, Rng::new(1), cmd_rx).expect("spawn worker");
        // Initial beacon plus cadence beacons.
        let msg = sub_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(matches!(msg, SubmasterMsg::Heartbeat(2)));
        let msg = sub_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(matches!(msg, SubmasterMsg::Heartbeat(2)));
        // Flipping the dead flag mid-run silences the beacons (drain
        // whatever was already in flight first).
        faults.set_worker_dead(0, 2, true);
        while sub_rx.recv_timeout(Duration::from_millis(50)).is_ok() {}
        assert!(sub_rx.recv_timeout(Duration::from_millis(100)).is_err());
        // Reviving restores them.
        faults.set_worker_dead(0, 2, false);
        assert!(sub_rx.recv_timeout(Duration::from_secs(5)).is_ok());
        cmd_tx.send(WorkerCmd::Shutdown).unwrap();
        h.join().unwrap();
    }
}
