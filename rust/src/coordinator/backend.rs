//! Worker compute backends: PJRT artifact execution or pure Rust.

use crate::linalg::{ops, Matrix};
use crate::runtime::{PjrtRuntime, Tensor32};
use crate::{Error, Result};

/// How a worker computes its shard product.
#[derive(Clone)]
pub enum ComputeBackend {
    /// Execute the AOT `worker_matvec_*` artifact through PJRT — the
    /// production path (L1 Pallas kernel → HLO → PJRT).
    Pjrt(PjrtRuntime),
    /// Pure-Rust `f64` GEMM — fallback for artifact-less test runs and
    /// the differential oracle for the PJRT path.
    Native,
}

impl ComputeBackend {
    /// Compute `shard · x` (`r×d · d×b`).
    pub fn shard_product(&self, shard: &WorkerShard, x: &Matrix) -> Result<Matrix> {
        match self {
            ComputeBackend::Native => Ok(ops::matmul(&shard.f64, x)),
            ComputeBackend::Pjrt(rt) => {
                let xt = Tensor32::from_matrix(x);
                let out = rt.execute_worker(&shard.f32, &xt)?;
                out.to_matrix()
            }
        }
    }

    /// Batch widths this backend can serve for a `(r, d)` shard.
    /// PJRT is restricted to the widths that were AOT-compiled;
    /// native handles anything.
    pub fn supported_batch_widths(&self, r: usize, d: usize) -> Option<Vec<usize>> {
        match self {
            ComputeBackend::Native => None, // unrestricted
            ComputeBackend::Pjrt(rt) => {
                let mut widths: Vec<usize> = rt
                    .manifest()
                    .entries()
                    .iter()
                    .filter(|e| {
                        e.entry == "worker_task"
                            && e.inputs.len() == 2
                            && e.inputs[0] == vec![r, d]
                    })
                    .map(|e| e.inputs[1][1])
                    .collect();
                widths.sort_unstable();
                widths.dedup();
                Some(widths)
            }
        }
    }
}

/// A worker's shard, stored in both precisions: `f32` feeds PJRT
/// artifacts, `f64` feeds the native fallback. The `f64` copy is the
/// `f32`-narrowed data widened back, so both backends compute from the
/// *same* values and agree to f32 rounding.
#[derive(Clone, Debug)]
pub struct WorkerShard {
    /// PJRT input.
    pub f32: Tensor32,
    /// Native-backend input (widened from the f32 data).
    pub f64: Matrix,
}

impl WorkerShard {
    /// Build from the encoder's `f64` shard.
    pub fn new(shard: &Matrix) -> Result<Self> {
        let f32 = Tensor32::from_matrix(shard);
        let f64 = f32.to_matrix()?;
        Ok(Self { f32, f64 })
    }

    /// Shard shape `(r, d)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.f64.rows(), self.f64.cols())
    }
}

/// Pick the batch width to compile a batch of `b` requests against:
/// smallest supported width ≥ `b` (requests are zero-padded up), or an
/// error if the artifact set can't serve `b`.
pub fn pick_batch_width(supported: Option<&[usize]>, b: usize) -> Result<usize> {
    match supported {
        None => Ok(b),
        Some(ws) => ws
            .iter()
            .copied()
            .find(|&w| w >= b)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no worker artifact supports batch width ≥ {b} (available: {ws:?}); \
                     add the shape to python/compile/aot.py"
                ))
            }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn native_backend_computes_product() {
        let mut r = Rng::new(1);
        let shard_m = Matrix::from_fn(8, 6, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(6, 2, |_, _| r.uniform(-1.0, 1.0));
        let shard = WorkerShard::new(&shard_m).unwrap();
        let out = ComputeBackend::Native.shard_product(&shard, &x).unwrap();
        // f32-narrowed shard vs f64 original: small tolerance.
        let expect = ops::matmul(&shard_m, &x);
        assert!(out.max_abs_diff(&expect) < 1e-5);
    }

    #[test]
    fn pick_batch_width_logic() {
        assert_eq!(pick_batch_width(None, 3).unwrap(), 3);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 1).unwrap(), 1);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 3).unwrap(), 4);
        assert_eq!(pick_batch_width(Some(&[1, 4, 8]), 8).unwrap(), 8);
        assert!(pick_batch_width(Some(&[1, 4]), 5).is_err());
    }

    #[test]
    fn pjrt_matches_native_backend() {
        let dir = crate::runtime::artifact::default_artifact_dir();
        if !crate::runtime::artifact::artifacts_available(&dir) {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = PjrtRuntime::start(dir).unwrap();
        let pjrt = ComputeBackend::Pjrt(rt);
        let mut r = Rng::new(2);
        // Matches artifact worker_matvec_r16_d32_b1.
        let shard_m = Matrix::from_fn(16, 32, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(32, 1, |_, _| r.uniform(-1.0, 1.0));
        let shard = WorkerShard::new(&shard_m).unwrap();
        let a = pjrt.shard_product(&shard, &x).unwrap();
        let b = ComputeBackend::Native.shard_product(&shard, &x).unwrap();
        assert!(
            a.max_abs_diff(&b) < 1e-4,
            "PJRT vs native differ by {}",
            a.max_abs_diff(&b)
        );
        // Supported widths discovered from the manifest.
        let widths = pjrt.supported_batch_widths(16, 32).unwrap();
        assert!(widths.contains(&1));
    }
}
