//! Failure injection for the in-process cluster.

use std::collections::HashSet;

/// Faults to inject into a launched cluster (fixed for its lifetime).
#[derive(Clone, Debug, Default)]
pub struct FaultConfig {
    /// Workers `(group, index)` that never produce results.
    pub dead_workers: HashSet<(usize, usize)>,
    /// Groups whose uplink to the master is severed (submaster decodes
    /// but deliveries are dropped).
    pub dead_links: HashSet<usize>,
}

impl FaultConfig {
    /// No faults.
    pub fn none() -> Self {
        Self::default()
    }

    /// Kill specific workers.
    pub fn with_dead_workers(mut self, ws: &[(usize, usize)]) -> Self {
        self.dead_workers.extend(ws.iter().copied());
        self
    }

    /// Sever specific group uplinks.
    pub fn with_dead_links(mut self, gs: &[usize]) -> Self {
        self.dead_links.extend(gs.iter().copied());
        self
    }

    /// Is this worker dead?
    pub fn worker_dead(&self, group: usize, index: usize) -> bool {
        self.dead_workers.contains(&(group, index))
    }

    /// Is this group's uplink dead?
    pub fn link_dead(&self, group: usize) -> bool {
        self.dead_links.contains(&group)
    }

    /// Whether an `(n1,k1)×(n2,k2)` deployment can still serve requests
    /// under these faults (used by tests to assert expected outcomes).
    pub fn survivable(&self, n1: usize, k1: usize, n2: usize, k2: usize) -> bool {
        let healthy_groups = (0..n2)
            .filter(|&g| {
                if self.link_dead(g) {
                    return false;
                }
                let alive = (0..n1).filter(|&w| !self.worker_dead(g, w)).count();
                alive >= k1
            })
            .count();
        healthy_groups >= k2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn survivability_logic() {
        let f = FaultConfig::none();
        assert!(f.survivable(3, 2, 3, 2));

        // One group fully dead: still k2 = 2 of 3.
        let f = FaultConfig::none().with_dead_links(&[0]);
        assert!(f.survivable(3, 2, 3, 2));

        // Two dead links: only 1 < k2 healthy groups.
        let f = FaultConfig::none().with_dead_links(&[0, 1]);
        assert!(!f.survivable(3, 2, 3, 2));

        // Worker attrition below k1 in two groups.
        let f = FaultConfig::none()
            .with_dead_workers(&[(0, 0), (0, 1), (1, 0), (1, 1)]);
        assert!(!f.survivable(3, 2, 3, 2));

        // Attrition to exactly k1 survives.
        let f = FaultConfig::none().with_dead_workers(&[(0, 0)]);
        assert!(f.survivable(3, 2, 3, 2));
    }
}
