//! Failure injection for the in-process cluster.
//!
//! Three layers, from static to dynamic:
//!
//! * [`FaultConfig`] — faults present from launch (dead workers,
//!   severed uplinks). Kept for scenario descriptions and merged into
//!   the live [`FaultState`] at launch.
//! * [`FaultState`] — the *live* fault switchboard shared by every
//!   coordinator thread: per-worker dead flags, per-group uplink
//!   sever flags and delay/drop degradation knobs, all atomics so the
//!   chaos driver can flip them mid-serve without locks.
//! * [`FaultPlan`] — a deterministic, seeded schedule of timed
//!   [`FaultEvent`]s (crash/restart, sever/heal, uplink degradation
//!   with bounded jitter) executed by the
//!   [`chaos`](crate::coordinator::chaos) driver thread. Same seed,
//!   same events — the chaos harness's determinism verdict rests on
//!   this.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::scenario::Topology;
use crate::util::rng::Rng;

/// Faults to inject into a cluster at launch time.
#[derive(Clone, Debug, Default)]
pub struct FaultConfig {
    /// Workers `(group, index)` that never produce results.
    pub dead_workers: HashSet<(usize, usize)>,
    /// Groups whose uplink to the master is severed (submaster decodes
    /// but deliveries are dropped).
    pub dead_links: HashSet<usize>,
}

impl FaultConfig {
    /// No faults.
    pub fn none() -> Self {
        Self::default()
    }

    /// Kill specific workers.
    pub fn with_dead_workers(mut self, ws: &[(usize, usize)]) -> Self {
        self.dead_workers.extend(ws.iter().copied());
        self
    }

    /// Sever specific group uplinks.
    pub fn with_dead_links(mut self, gs: &[usize]) -> Self {
        self.dead_links.extend(gs.iter().copied());
        self
    }

    /// Is this worker dead?
    pub fn worker_dead(&self, group: usize, index: usize) -> bool {
        self.dead_workers.contains(&(group, index))
    }

    /// Is this group's uplink dead?
    pub fn link_dead(&self, group: usize) -> bool {
        self.dead_links.contains(&group)
    }

    /// Whether an `(n1,k1)×(n2,k2)` deployment can still serve requests
    /// under these faults.
    ///
    /// Assumes a *uniform* code: every group the same `(n1, k1)`, one
    /// sub-task per worker. Heterogeneous topologies (per-group
    /// `n1_g`/`k1_g`, scenario-level dead workers, partial-work `r`)
    /// need [`FaultConfig::survivable_for`].
    #[deprecated(
        since = "0.3.0",
        note = "uniform-code only; use survivable_for(&Topology), which \
                honors per-group (n1_g, k1_g), scenario dead workers and \
                partial-work sub-tasks"
    )]
    pub fn survivable(&self, n1: usize, k1: usize, n2: usize, k2: usize) -> bool {
        self.survivable_for(&Topology::homogeneous(n1, k1, n2, k2))
    }

    /// Whether `topo` can still serve requests under these faults.
    ///
    /// A group is healthy when its uplink is alive and its reachable
    /// sub-results — alive workers (neither scenario-dead nor
    /// fault-dead) times `subtasks` per worker — still meet the group
    /// recovery threshold `k1_g · r`. The deployment serves while at
    /// least `k2` groups are healthy. This is exactly the degradation
    /// threshold the master's failure detector enforces at runtime.
    pub fn survivable_for(&self, topo: &Topology) -> bool {
        let healthy = topo
            .groups
            .iter()
            .enumerate()
            .filter(|(g, spec)| {
                if self.link_dead(*g) {
                    return false;
                }
                let alive = (0..spec.n1)
                    .filter(|&j| {
                        !self.worker_dead(*g, j) && !spec.dead_workers.contains(&j)
                    })
                    .count();
                alive * spec.subtasks >= spec.recovery_subresults()
            })
            .count();
        healthy >= topo.k2
    }
}

/// Sentinel meaning "no injected uplink delay".
const NO_DELAY_BITS: u64 = 0;

/// Live fault switchboard shared across the coordinator tree.
///
/// Workers consult their dead flag before computing or heartbeating;
/// submasters consult the link flag and degradation knobs before
/// shipping a partial upstream; the chaos driver and the cluster
/// supervisor flip them. All fields are atomics — reads on the request
/// hot path are wait-free, and out-of-range indices are treated as
/// "no fault" rather than panicking.
#[derive(Debug)]
pub struct FaultState {
    /// Per-worker dead flags, indexed `[group][index]`.
    workers: Vec<Vec<AtomicBool>>,
    /// Per-group uplink sever flags.
    links: Vec<AtomicBool>,
    /// Per-group injected uplink delay ceiling, f64 milliseconds as
    /// bits (0 = none; actual delay is uniform in `[0, ceiling)`).
    uplink_delay_bits: Vec<AtomicU64>,
    /// Per-group injected uplink loss, in dropped partials per 1000.
    uplink_drop_per_mille: Vec<AtomicU64>,
    /// Partials dropped by injected loss (observability counter).
    dropped: AtomicU64,
}

impl FaultState {
    /// All-healthy state for groups of the given sizes.
    pub fn new(group_sizes: &[usize]) -> Self {
        Self {
            workers: group_sizes
                .iter()
                .map(|&n| (0..n).map(|_| AtomicBool::new(false)).collect())
                .collect(),
            links: group_sizes.iter().map(|_| AtomicBool::new(false)).collect(),
            uplink_delay_bits: group_sizes
                .iter()
                .map(|_| AtomicU64::new(NO_DELAY_BITS))
                .collect(),
            uplink_drop_per_mille: group_sizes.iter().map(|_| AtomicU64::new(0)).collect(),
            dropped: AtomicU64::new(0),
        }
    }

    /// State seeded from a launch-time [`FaultConfig`].
    pub fn from_config(group_sizes: &[usize], cfg: &FaultConfig) -> Self {
        let s = Self::new(group_sizes);
        for &(g, j) in &cfg.dead_workers {
            s.set_worker_dead(g, j, true);
        }
        for &g in &cfg.dead_links {
            s.set_link_dead(g, true);
        }
        s
    }

    /// Number of groups tracked.
    pub fn n_groups(&self) -> usize {
        self.links.len()
    }

    /// Is this worker currently dead? Out-of-range ⇒ `false`.
    pub fn worker_dead(&self, group: usize, index: usize) -> bool {
        self.workers
            .get(group)
            .and_then(|g| g.get(index))
            .map(|b| b.load(Ordering::SeqCst))
            .unwrap_or(false)
    }

    /// Flip a worker's dead flag. Out-of-range ⇒ no-op.
    pub fn set_worker_dead(&self, group: usize, index: usize, dead: bool) {
        if let Some(b) = self.workers.get(group).and_then(|g| g.get(index)) {
            b.store(dead, Ordering::SeqCst);
        }
    }

    /// Workers of `group` currently not dead.
    pub fn alive_in_group(&self, group: usize) -> usize {
        self.workers
            .get(group)
            .map(|g| g.iter().filter(|b| !b.load(Ordering::SeqCst)).count())
            .unwrap_or(0)
    }

    /// Is this group's uplink currently severed? Out-of-range ⇒ `false`.
    pub fn link_dead(&self, group: usize) -> bool {
        self.links
            .get(group)
            .map(|b| b.load(Ordering::SeqCst))
            .unwrap_or(false)
    }

    /// Flip a group's uplink sever flag. Out-of-range ⇒ no-op.
    pub fn set_link_dead(&self, group: usize, dead: bool) {
        if let Some(b) = self.links.get(group) {
            b.store(dead, Ordering::SeqCst);
        }
    }

    /// Degrade a group's uplink: every shipped partial gains a delay
    /// uniform in `[0, delay_ms)` and is dropped with probability
    /// `drop_per_mille / 1000`. `(0.0, 0)` restores the link.
    pub fn set_uplink_degrade(&self, group: usize, delay_ms: f64, drop_per_mille: u64) {
        if let Some(d) = self.uplink_delay_bits.get(group) {
            let ceil = if delay_ms.is_finite() && delay_ms > 0.0 {
                delay_ms
            } else {
                0.0
            };
            d.store(ceil.to_bits(), Ordering::SeqCst);
        }
        if let Some(p) = self.uplink_drop_per_mille.get(group) {
            p.store(drop_per_mille.min(1000), Ordering::SeqCst);
        }
    }

    /// Current injected uplink delay ceiling for `group`, ms (0 = none).
    pub fn uplink_delay_ms(&self, group: usize) -> f64 {
        self.uplink_delay_bits
            .get(group)
            .map(|d| f64::from_bits(d.load(Ordering::SeqCst)))
            .unwrap_or(0.0)
    }

    /// Current injected uplink loss for `group`, per 1000 partials.
    pub fn uplink_drop_per_mille(&self, group: usize) -> u64 {
        self.uplink_drop_per_mille
            .get(group)
            .map(|p| p.load(Ordering::SeqCst))
            .unwrap_or(0)
    }

    /// Count one partial dropped by injected loss.
    pub fn record_dropped(&self) {
        self.dropped.fetch_add(1, Ordering::SeqCst);
    }

    /// Partials dropped by injected loss so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::SeqCst)
    }
}

/// One timed fault action.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultAction {
    /// Kill worker `(group, index)`: its thread exits, its loaded
    /// shards are gone until a restart re-ships them.
    WorkerCrash { group: usize, index: usize },
    /// Respawn worker `(group, index)` and re-ship its shards for
    /// every registered model.
    WorkerRestart { group: usize, index: usize },
    /// Sever a group's uplink: partials and heartbeats stop reaching
    /// the master.
    LinkSever { group: usize },
    /// Restore a severed uplink.
    LinkHeal { group: usize },
    /// Degrade a group's uplink: per-partial delay uniform in
    /// `[0, delay_ms)`, loss at `drop_per_mille / 1000`.
    /// `(0.0, 0)` heals the degradation.
    UplinkDegrade {
        group: usize,
        delay_ms: f64,
        drop_per_mille: u64,
    },
}

/// A [`FaultAction`] at a point in time (ms from serve start).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    /// When to fire, milliseconds after the chaos driver starts.
    pub at_ms: u64,
    /// What to do.
    pub action: FaultAction,
}

/// A deterministic schedule of timed fault events, kept sorted by
/// firing time (stable for ties: insertion order).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Empty plan.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an event, keeping the schedule sorted by time (builder).
    pub fn at(mut self, at_ms: u64, action: FaultAction) -> Self {
        let pos = self.events.partition_point(|e| e.at_ms <= at_ms);
        self.events.insert(pos, FaultEvent { at_ms, action });
        self
    }

    /// The schedule, sorted by firing time.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the schedule is empty.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Seeded churn schedule that never breaks survivability: in
    /// rounds of `period_ms`, each group with spare redundancy
    /// (`alive > k1`) crashes one randomly chosen non-scenario-dead
    /// worker at a jittered time and restarts it well before the next
    /// round. At every instant each group keeps ≥ `k1_g · r` reachable
    /// sub-results and every uplink stays alive, so ≥ `k2` groups stay
    /// healthy throughout — jobs under this plan must all complete.
    ///
    /// Deterministic: same `(seed, topo, duration_ms, period_ms)` ⇒
    /// same schedule, event for event.
    pub fn survivable_churn(
        seed: u64,
        topo: &Topology,
        duration_ms: u64,
        period_ms: u64,
    ) -> Self {
        let period = period_ms.max(8);
        let jitter = |rng: &mut Rng, bound: u64| -> u64 {
            if bound == 0 {
                0
            } else {
                rng.next_u64() % bound
            }
        };
        let mut rng = Rng::new(seed);
        let mut plan = Self::new();
        // Downtime fits inside the round: crash at t+[0,p/4), restart
        // at crash + p/3 + [0,p/8) < t + p.
        let mut t = period / 2;
        while t + period < duration_ms {
            for (g, spec) in topo.groups.iter().enumerate() {
                // Candidates: workers the scenario hasn't already
                // killed. Crash one only if the group keeps >= k1.
                let candidates: Vec<usize> = (0..spec.n1)
                    .filter(|j| !spec.dead_workers.contains(j))
                    .collect();
                if candidates.len() <= spec.k1 {
                    continue; // no spare redundancy in this group
                }
                let pick = candidates[(rng.next_u64() as usize) % candidates.len()];
                let crash_at = t + jitter(&mut rng, period / 4);
                let down = period / 3 + jitter(&mut rng, period / 8);
                plan = plan
                    .at(crash_at, FaultAction::WorkerCrash { group: g, index: pick })
                    .at(
                        crash_at + down.max(1),
                        FaultAction::WorkerRestart { group: g, index: pick },
                    );
            }
            t += period;
        }
        plan
    }

    /// Seeded schedule that breaks survivability: severs
    /// `n2 - k2 + 1` uplinks (chosen by a seeded rotation) at jittered
    /// times near `at_ms`, and never heals them. Fewer than `k2`
    /// groups stay healthy, so jobs in flight or submitted afterwards
    /// must fail fast with `Error::Insufficient`.
    pub fn unsurvivable_severs(seed: u64, topo: &Topology, at_ms: u64) -> Self {
        let n2 = topo.n2();
        let to_sever = n2 - topo.k2 + 1;
        let mut rng = Rng::new(seed);
        let start = (rng.next_u64() as usize) % n2.max(1);
        let mut plan = Self::new();
        for i in 0..to_sever {
            let g = (start + i) % n2;
            let when = at_ms + rng.next_u64() % 40;
            plan = plan.at(when, FaultAction::LinkSever { group: g });
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[allow(deprecated)]
    fn survivability_logic_uniform() {
        let f = FaultConfig::none();
        assert!(f.survivable(3, 2, 3, 2));

        // One group fully dead: still k2 = 2 of 3.
        let f = FaultConfig::none().with_dead_links(&[0]);
        assert!(f.survivable(3, 2, 3, 2));

        // Two dead links: only 1 < k2 healthy groups.
        let f = FaultConfig::none().with_dead_links(&[0, 1]);
        assert!(!f.survivable(3, 2, 3, 2));

        // Worker attrition below k1 in two groups.
        let f = FaultConfig::none()
            .with_dead_workers(&[(0, 0), (0, 1), (1, 0), (1, 1)]);
        assert!(!f.survivable(3, 2, 3, 2));

        // Attrition to exactly k1 survives.
        let f = FaultConfig::none().with_dead_workers(&[(0, 0)]);
        assert!(f.survivable(3, 2, 3, 2));
    }

    #[test]
    fn survivability_is_topology_aware() {
        // Heterogeneous: group 0 is (2,1), group 1 is (4,3), k2 = 2.
        let mut topo = Topology {
            groups: vec![GroupSpecHelper::new(2, 1), GroupSpecHelper::new(4, 3)],
            k2: 2,
        };
        // Uniform form (fed max n1) would think killing worker (0,1)
        // leaves plenty; topology form knows group 0 only has 2.
        let f = FaultConfig::none().with_dead_workers(&[(0, 0), (0, 1)]);
        assert!(!f.survivable_for(&topo));
        // One dead in the (4,3) group: 3 alive >= k1 = 3, survivable.
        let f = FaultConfig::none().with_dead_workers(&[(1, 0)]);
        assert!(f.survivable_for(&topo));
        // Scenario-level dead workers are merged in: group 1 already
        // lost a worker in the spec, so one more fault kills it.
        topo.groups[1].dead_workers = vec![3];
        assert!(!f.survivable_for(&topo));
        // Severed link overrides worker health.
        let f = FaultConfig::none().with_dead_links(&[0]);
        topo.groups[1].dead_workers = vec![];
        assert!(!f.survivable_for(&topo), "group 1 alone < k2 = 2");
    }

    use crate::scenario::GroupSpec as GroupSpecHelper;

    #[test]
    fn deprecated_uniform_form_delegates() {
        // The uniform form must agree with the topology form on the
        // homogeneous expansion it documents.
        let f = FaultConfig::none().with_dead_links(&[0, 1]);
        #[allow(deprecated)]
        let uniform = f.survivable(3, 2, 3, 2);
        assert_eq!(uniform, f.survivable_for(&Topology::homogeneous(3, 2, 3, 2)));
    }

    #[test]
    fn fault_state_flips_and_bounds() {
        let s = FaultState::new(&[3, 2]);
        assert_eq!(s.n_groups(), 2);
        assert!(!s.worker_dead(0, 1));
        s.set_worker_dead(0, 1, true);
        assert!(s.worker_dead(0, 1));
        assert_eq!(s.alive_in_group(0), 2);
        s.set_worker_dead(0, 1, false);
        assert_eq!(s.alive_in_group(0), 3);
        // Out-of-range reads are "no fault", writes are no-ops.
        assert!(!s.worker_dead(7, 7));
        s.set_worker_dead(7, 7, true);
        assert!(!s.link_dead(9));
        s.set_link_dead(0, true);
        assert!(s.link_dead(0));
        // Degradation knobs round-trip; garbage is clamped.
        s.set_uplink_degrade(1, 5.0, 250);
        assert_eq!(s.uplink_delay_ms(1), 5.0);
        assert_eq!(s.uplink_drop_per_mille(1), 250);
        s.set_uplink_degrade(1, f64::NAN, 5000);
        assert_eq!(s.uplink_delay_ms(1), 0.0);
        assert_eq!(s.uplink_drop_per_mille(1), 1000);
        s.record_dropped();
        assert_eq!(s.dropped(), 1);
    }

    #[test]
    fn fault_state_from_config_merges() {
        let cfg = FaultConfig::none()
            .with_dead_workers(&[(0, 2), (1, 0)])
            .with_dead_links(&[1]);
        let s = FaultState::from_config(&[3, 3], &cfg);
        assert!(s.worker_dead(0, 2));
        assert!(s.worker_dead(1, 0));
        assert!(!s.worker_dead(0, 0));
        assert!(s.link_dead(1));
        assert!(!s.link_dead(0));
    }

    #[test]
    fn plan_builder_keeps_schedule_sorted() {
        let plan = FaultPlan::new()
            .at(50, FaultAction::LinkSever { group: 1 })
            .at(10, FaultAction::WorkerCrash { group: 0, index: 2 })
            .at(50, FaultAction::LinkHeal { group: 1 });
        let times: Vec<u64> = plan.events().iter().map(|e| e.at_ms).collect();
        assert_eq!(times, vec![10, 50, 50]);
        // Stable for ties: sever inserted before heal stays first.
        assert_eq!(plan.events()[1].action, FaultAction::LinkSever { group: 1 });
        assert_eq!(plan.len(), 3);
        assert!(!plan.is_empty());
    }

    #[test]
    fn survivable_churn_is_deterministic_and_survivable() {
        let topo = Topology::homogeneous(3, 2, 3, 2);
        let a = FaultPlan::survivable_churn(7, &topo, 2000, 250);
        let b = FaultPlan::survivable_churn(7, &topo, 2000, 250);
        assert_eq!(a, b, "same seed, same schedule");
        let c = FaultPlan::survivable_churn(8, &topo, 2000, 250);
        assert_ne!(a, c, "different seed perturbs the schedule");
        assert!(!a.is_empty());

        // Replay the schedule: at every instant each group keeps
        // >= k1 alive workers (crash is always paired with a restart,
        // one victim per group per round).
        let mut dead: Vec<Vec<bool>> = topo.groups.iter().map(|g| vec![false; g.n1]).collect();
        for e in a.events() {
            match e.action {
                FaultAction::WorkerCrash { group, index } => {
                    dead[group][index] = true;
                    let alive = dead[group].iter().filter(|d| !**d).count();
                    assert!(alive >= topo.groups[group].k1, "never below k1");
                }
                FaultAction::WorkerRestart { group, index } => dead[group][index] = false,
                _ => panic!("churn plan only crashes and restarts"),
            }
        }
        assert!(
            dead.iter().flatten().all(|d| !d),
            "every crash is healed by the end of the plan"
        );
    }

    #[test]
    fn churn_skips_groups_without_redundancy() {
        // (1,1) groups have no spare worker: the plan must leave them
        // alone entirely rather than break survivability.
        let topo = Topology::homogeneous(1, 1, 3, 2);
        let plan = FaultPlan::survivable_churn(7, &topo, 5000, 200);
        assert!(plan.is_empty());
    }

    #[test]
    fn unsurvivable_severs_break_k2() {
        let topo = Topology::homogeneous(3, 2, 3, 2);
        let plan = FaultPlan::unsurvivable_severs(11, &topo, 100);
        assert_eq!(plan.len(), 3 - 2 + 1);
        let mut cfg = FaultConfig::none();
        for e in plan.events() {
            assert!(e.at_ms >= 100 && e.at_ms < 140, "bounded jitter");
            match e.action {
                FaultAction::LinkSever { group } => {
                    cfg = cfg.with_dead_links(&[group]);
                }
                _ => panic!("sever-only plan"),
            }
        }
        assert!(!cfg.survivable_for(&topo));
        assert_eq!(
            plan,
            FaultPlan::unsurvivable_severs(11, &topo, 100),
            "seeded: replayable event for event"
        );
    }
}
