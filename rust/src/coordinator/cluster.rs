//! The multi-tenant job service facade.
//!
//! The serving API splits ownership in two:
//!
//! * [`ClusterCore`] **owns** the thread tree (master, submasters,
//!   workers, batcher) and the model registry. It launches from config
//!   alone — no matrix — and named computations ("models") are
//!   registered at runtime with [`ClusterCore::register_model`]: each
//!   registration encodes the matrix and ships one shard per worker.
//! * [`ClientHandle`] is the cheap, cloneable, `Send` submission
//!   surface handed to every tenant. Each submission carries
//!   [`SubmitOptions`] (model name, deadline, priority) and passes
//!   **admission control**: a bounded per-model queue that bounces
//!   excess submissions with [`Error::Busy`] instead of buffering
//!   without bound, plus deadline-expired shedding downstream in the
//!   batcher and master.
//!
//! A submission yields a [`JobHandle`] backed by a shared completion
//! slot — `try_wait` polls, `wait`/`wait_timeout` block — so handles
//! can cross threads freely. Graceful shutdown **drains**: accepted
//! work is completed (or failed within the drain grace); no handle ever
//! hangs.
//!
//! The cluster is generic over [`CodedScheme`]: `config.code.scheme`
//! selects `hierarchical | mds | product | replication | polynomial`,
//! and the same master/submaster/worker topology serves all of them —
//! schemes with splittable decodes (hierarchical) decode inside the
//! submasters, the rest relay raw products to the master's streaming
//! decode session.
//!
//! [`Cluster`] remains as the single-tenant convenience facade
//! (`launch(&config, &A)` = core + one model named
//! [`DEFAULT_MODEL`]).
//!
//! # Hot reload
//!
//! The core is also the control plane's execution target: it tracks
//! the compiled scenario artifact it was launched from as a
//! generation-stamped `ActiveArtifact`, and
//! [`ClusterCore::load_artifact`] hot-swaps to a new artifact without
//! dropping in-flight jobs. **Light** rollouts (model table, serving
//! limits, batching knobs — see [`controlplane::classify`]) apply
//! in-place through atomics and registry updates. **Heavy** rollouts
//! (a changed per-group `k1` recovery-threshold plan) re-encode every
//! retained model under the new scheme *first*, then quiesce — pause
//! the batcher (buffering, not bouncing, new work) and wait for the
//! master to report zero in-flight jobs — cut over (re-ship shards,
//! [`MasterMsg::Reconfigure`], [`SubmasterMsg::Swap`]), and resume.
//! Any validation failure before the cut-over leaves the cluster
//! untouched ([`Error::Incompatible`]); [`ClusterCore::rollback`]
//! restores the previous generation through the same machinery.

use crate::coding::CodedScheme;
use crate::controlplane::{self, AdminControl, RolloutKind};
use crate::coordinator::backend::{ComputeBackend, WorkerShard};
use crate::coordinator::batcher::{self, BatcherControl};
use crate::coordinator::chaos::{FaultInjector, LivenessConfig};
use crate::coordinator::fault::{FaultConfig, FaultState};
use crate::coordinator::master;
use crate::coordinator::messages::{
    CompletionSlot, JobRequest, MasterMsg, ModelEntry, ModelId, RequestId,
    SchemeSwap, SubmasterMsg, WorkerCmd, WorkerLink,
};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot, ModelMetricsSnapshot};
use crate::coordinator::submaster::{self, LinkDelay};
use crate::coordinator::worker::{self, WorkerCtx, WorkerDelay};
use crate::config::schema::{ClusterConfig, TransportMode};
use crate::linalg::lu::LuCacheStats;
use crate::linalg::{LuCache, Matrix};
use crate::runtime::PjrtRuntime;
use crate::sync::{Mutex, RwLock, WallClock};
use crate::transport::memory::MemoryTransport;
use crate::transport::socket::SocketHub;
use crate::transport::{Transport, TransportAddr};
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

/// The model name [`Cluster::launch`] registers its matrix under, and
/// the default target of [`SubmitOptions`].
pub const DEFAULT_MODEL: &str = "default";

/// Per-submission options: which model, how long the request may wait
/// for dispatch, and its batching priority.
#[derive(Clone, Debug)]
pub struct SubmitOptions {
    /// Target model name (default [`DEFAULT_MODEL`]).
    pub model: String,
    /// Admission deadline: if the request is still queued (batcher or
    /// master inbox) past this duration it is shed with
    /// [`Error::DeadlineExceeded`]. `None` = the config's
    /// `serving.default_deadline_ms`.
    pub deadline: Option<Duration>,
    /// Batching priority: higher dispatches first within a flush
    /// (FIFO among equals). Default 0.
    pub priority: i32,
}

impl Default for SubmitOptions {
    fn default() -> Self {
        Self {
            model: DEFAULT_MODEL.to_string(),
            deadline: None,
            priority: 0,
        }
    }
}

impl SubmitOptions {
    /// Options targeting `model` with default deadline and priority.
    pub fn to_model(model: &str) -> Self {
        Self {
            model: model.to_string(),
            ..Self::default()
        }
    }

    /// Set an explicit admission deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Set the batching priority.
    pub fn with_priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }
}

/// State shared between the core and every client handle.
struct ServiceState {
    /// Registered models by name.
    models: RwLock<HashMap<String, Arc<ModelEntry>>>,
    /// The batcher's request channel. `shutdown` takes it; submissions
    /// clone the sender under the read lock, so every send that
    /// succeeds is processed before the batcher sees disconnect —
    /// accepted work is never dropped.
    req_tx: RwLock<Option<mpsc::Sender<JobRequest>>>,
    /// Master channel (for cancellation).
    master_tx: mpsc::Sender<MasterMsg>,
    /// Shared metrics sink.
    metrics: Arc<Metrics>,
    /// Flips false at shutdown: new submissions are refused.
    accepting: AtomicBool,
    /// Request-id allocator.
    next_req: AtomicU64,
    /// Applied when `SubmitOptions::deadline` is `None`, in
    /// microseconds — atomic so a light rollout can retune it while
    /// submissions race.
    default_deadline_us: AtomicU64,
}

/// Handle to one in-flight request, backed by a shared completion slot:
/// `Send`, pollable, and guaranteed to resolve — the drain protocol
/// completes or fails every accepted request's slot.
#[derive(Debug)]
pub struct JobHandle {
    slot: Arc<CompletionSlot>,
    master: mpsc::Sender<MasterMsg>,
    req_id: RequestId,
}

impl JobHandle {
    /// Block until the result arrives.
    pub fn wait(self) -> Result<Vec<f64>> {
        self.slot.wait().map_err(Error::from)
    }

    /// Block with a timeout. On timeout the request is **cancelled**:
    /// the master drops its reply route and, once no client waits on
    /// the underlying job, cancels the job itself — so abandoned jobs
    /// leak neither decode work nor master-side state.
    pub fn wait_timeout(self, timeout: Duration) -> Result<Vec<f64>> {
        match self.slot.wait_timeout(timeout) {
            Some(outcome) => outcome.map_err(Error::from),
            None => {
                let _ = self.master.send(MasterMsg::CancelRequest(self.req_id));
                Err(Error::Coordinator("request timed out".into()))
            }
        }
    }

    /// Non-blocking poll: `Some` exactly once, when the outcome is in.
    pub fn try_wait(&self) -> Option<Result<Vec<f64>>> {
        self.slot.try_take().map(|r| r.map_err(Error::from))
    }

    /// Abandon the request without waiting.
    pub fn cancel(self) {
        let _ = self.master.send(MasterMsg::CancelRequest(self.req_id));
    }
}

/// A cheap, cloneable, `Send + Sync` submission surface onto a running
/// [`ClusterCore`]. Every tenant thread gets its own clone.
#[derive(Clone)]
pub struct ClientHandle {
    state: Arc<ServiceState>,
}

impl ClientHandle {
    /// Submit `x` to the default model with default options.
    pub fn submit(&self, x: Vec<f64>) -> Result<JobHandle> {
        self.submit_with(x, SubmitOptions::default())
    }

    /// Submit `x` to a named model with default options.
    pub fn submit_to(&self, model: &str, x: Vec<f64>) -> Result<JobHandle> {
        self.submit_with(x, SubmitOptions::to_model(model))
    }

    /// Submit `x` with full [`SubmitOptions`]. Nonblocking: admission
    /// control answers immediately — [`Error::Busy`] when the model's
    /// queue is at capacity, [`Error::InvalidParams`] for unknown
    /// models or dimension mismatches.
    pub fn submit_with(&self, x: Vec<f64>, opts: SubmitOptions) -> Result<JobHandle> {
        if !self.state.accepting.load(Ordering::Acquire) {
            return Err(Error::Coordinator("cluster is shutting down".into()));
        }
        let entry = self
            .state
            .models
            .read()
            .get(&opts.model)
            .cloned()
            .ok_or_else(|| {
                Error::InvalidParams(format!(
                    "unknown model '{}' (register it on the ClusterCore first)",
                    opts.model
                ))
            })?;
        if x.len() != entry.d {
            return Err(Error::InvalidParams(format!(
                "request dimension {} != model '{}' dimension {}",
                x.len(),
                entry.name,
                entry.d
            )));
        }
        // Admission control: reserve a queue slot or bounce. The
        // reservation is released by the batcher at dispatch or shed.
        if !entry.admission.try_reserve() {
            Metrics::inc(&self.state.metrics.rejected);
            Metrics::inc(&entry.rejected);
            return Err(Error::Busy {
                model: entry.name.clone(),
            });
        }
        Metrics::inc(&self.state.metrics.queue_depth);
        Metrics::inc(&self.state.metrics.requests);
        Metrics::inc(&entry.accepted);
        let submitted_at = Instant::now();
        let deadline = submitted_at
            + opts.deadline.unwrap_or_else(|| {
                Duration::from_micros(
                    self.state.default_deadline_us.load(Ordering::Relaxed),
                )
            });
        let req_id = RequestId(self.state.next_req.fetch_add(1, Ordering::Relaxed));
        let slot = Arc::new(CompletionSlot::new());
        // Send under the read lock: a send that succeeds is then
        // guaranteed to precede the batcher's disconnect (shutdown
        // takes the sender under the write lock). The channel is
        // unbounded, so this send never blocks while the lock is held
        // — allowlisted for the lock-discipline lint.
        let sent = {
            let guard = self.state.req_tx.read();
            match guard.as_ref() {
                Some(tx) => tx
                    .send(JobRequest {
                        entry: Arc::clone(&entry),
                        x,
                        slot: Arc::clone(&slot),
                        submitted_at,
                        deadline,
                        priority: opts.priority,
                        req_id,
                    })
                    .is_ok(),
                None => false,
            }
        };
        if !sent {
            // Shutdown raced us: roll the reservation back.
            Metrics::dec(&self.state.metrics.queue_depth);
            entry.admission.release();
            Metrics::dec(&self.state.metrics.requests);
            Metrics::dec(&entry.accepted);
            return Err(Error::Coordinator("cluster is shutting down".into()));
        }
        Ok(JobHandle {
            slot,
            master: self.state.master_tx.clone(),
            req_id,
        })
    }

    /// `(rows, cols)` of a registered model, or `None` if unknown.
    pub fn model_dims(&self, model: &str) -> Option<(usize, usize)> {
        self.state.models.read().get(model).map(|e| (e.m, e.d))
    }
}

/// One worker's supervision record: everything needed to respawn the
/// worker after a chaos crash with the exact same wiring.
struct Seat {
    /// Spawn context, retained verbatim for respawns.
    ctx: WorkerCtx,
    /// The live command channel; respawns swap the sender in place.
    link: WorkerLink,
    /// The worker's launch-time RNG seed; respawns derive a fresh
    /// stream from it so straggler draws stay deterministic per seat.
    seed: u64,
}

/// The cluster's recovery arm: owns every worker's [`Seat`], the live
/// [`FaultState`] switchboard, and a copy of each registered model's
/// encoded shards, so it can crash a worker (mark dead + stop its
/// thread) and later restart it (respawn + re-ship every shard through
/// [`WorkerCmd::Load`] before the new channel goes live). Implements
/// [`FaultInjector`], so a [`crate::coordinator::chaos`] driver can
/// replay a [`crate::coordinator::fault::FaultPlan`] against it.
pub struct Supervisor {
    /// Seats in flat `(group, index)` order.
    seats: Vec<Seat>,
    /// Flat index of each group's first worker.
    group_offsets: Vec<usize>,
    /// Workers per group.
    group_sizes: Vec<usize>,
    /// Live fault switchboard shared with every thread.
    faults: Arc<FaultState>,
    /// Encoded shards per model, in flat worker order — retained so a
    /// restarted worker can be re-shipped everything it lost.
    model_shards: Mutex<Vec<(ModelId, Vec<WorkerShard>)>>,
    /// Threads created by restarts, joined at shutdown.
    respawned: Mutex<Vec<thread::JoinHandle<()>>>,
    /// Bumped per restart: salts the respawned worker's RNG stream.
    generation: AtomicU64,
    /// The serving scheme's erasure-pattern LU caches, dropped whenever
    /// shards are (re-)shipped — see
    /// [`Supervisor::invalidate_decode_caches`]. Behind a mutex so a
    /// heavy rollout can swap in the replacement scheme's caches.
    caches: Mutex<Vec<Arc<LuCache>>>,
}

impl Supervisor {
    fn seat(&self, group: usize, index: usize) -> Option<&Seat> {
        let off = *self.group_offsets.get(group)?;
        if index >= self.group_sizes.get(group).copied().unwrap_or(0) {
            return None;
        }
        self.seats.get(off + index)
    }

    /// Retain a registered model's shards for future re-ships. Must be
    /// called **before** the registration ships its Loads: a restart
    /// snapshots this table while it holds the link write lock, so
    /// append-then-ship on one side and swap-then-snapshot on the
    /// other guarantee no Load is lost to the race (at worst a shard
    /// is shipped twice, and re-Loading identical data is idempotent).
    fn retain_model(&self, id: ModelId, shards: Vec<WorkerShard>) {
        self.model_shards.lock().push((id, shards));
    }

    /// Replace a retained model's shards in place (heavy rollout
    /// re-encode). Falls back to an append if the id is unknown, which
    /// keeps the restart re-ship path correct either way.
    fn replace_model(&self, id: ModelId, shards: Vec<WorkerShard>) {
        let mut table = self.model_shards.lock();
        match table.iter_mut().find(|(mid, _)| *mid == id) {
            Some(slot) => slot.1 = shards,
            None => table.push((id, shards)),
        }
    }

    /// Drop a retained model's shards (light rollout removal): a
    /// restarted worker no longer re-loads it.
    fn forget_model(&self, id: ModelId) {
        self.model_shards.lock().retain(|(mid, _)| *mid != id);
    }

    /// Swap in a replacement scheme's decode caches (heavy rollout).
    fn set_decode_caches(&self, caches: Vec<Arc<LuCache>>) {
        *self.caches.lock() = caches;
    }

    /// The live fault switchboard (tests and the chaos CLI flip it).
    pub fn fault_state(&self) -> &Arc<FaultState> {
        &self.faults
    }

    /// Partials dropped so far by injected uplink loss.
    pub fn injected_drops(&self) -> u64 {
        self.faults.dropped()
    }

    /// Drop every memoized decode factorization. Called after model
    /// (re-)registration and after a worker restart re-ships shards.
    /// The memoized factors depend only on the scheme's generators, but
    /// shard shipping is the conservative invalidation boundary — a
    /// stale-entry bug is ruled out by construction instead of argued
    /// about. Dropped entries count as evictions in the cache stats.
    pub fn invalidate_decode_caches(&self) {
        for cache in self.caches.lock().iter() {
            cache.invalidate_all();
        }
    }

    /// Aggregated stats across the scheme's decode caches (all zeros /
    /// NaN hit-rate for schemes without caches).
    pub fn decode_cache_stats(&self) -> LuCacheStats {
        self.caches
            .lock()
            .iter()
            .map(|c| c.stats())
            .fold(LuCacheStats::default(), LuCacheStats::merge)
    }
}

impl FaultInjector for Supervisor {
    fn worker_crash(&self, group: usize, index: usize) {
        let Some(seat) = self.seat(group, index) else {
            return;
        };
        // Dead flag first: the thread must not beacon between the
        // Shutdown send and its exit.
        self.faults.set_worker_dead(group, index, true);
        let _ = seat.link.read().send(WorkerCmd::Shutdown);
        crate::log_debug!("cluster", "chaos: crashed worker w({group},{index})");
    }

    fn worker_restart(&self, group: usize, index: usize) -> f64 {
        let started = Instant::now();
        let Some(seat) = self.seat(group, index) else {
            return f64::NAN;
        };
        let flat = self.group_offsets.get(group).copied().unwrap_or(0) + index;
        let generation = self.generation.fetch_add(1, Ordering::Relaxed) + 1;
        let (tx, rx) = mpsc::channel::<WorkerCmd>();
        // Revive before spawning so the new thread's initial beacon
        // isn't suppressed by its own dead flag.
        self.faults.set_worker_dead(group, index, false);
        let spawned = {
            let mut link = seat.link.write();
            // Idempotent with a prior crash; also makes a restart
            // without one safe (the orphaned thread still exits).
            let _ = link.send(WorkerCmd::Shutdown);
            // Snapshot *inside* the link write lock: every model either
            // appears here or will ship its Load through the new sender
            // (see `retain_model`).
            let loads: Vec<(ModelId, WorkerShard)> = self
                .model_shards
                .lock()
                .iter()
                .filter_map(|(id, shards)| Some((*id, shards.get(flat)?.clone())))
                .collect();
            let spawned = worker::spawn(
                seat.ctx.clone(),
                Rng::new(seat.seed ^ generation.wrapping_mul(0x9e37_79b9_7f4a_7c15)),
                rx,
            );
            if spawned.is_ok() {
                // Loads precede the sender swap, so any Compute routed
                // through the new channel finds its shards installed.
                for (id, ws) in loads {
                    let _ = tx.send(WorkerCmd::Load {
                        model: id,
                        shard: Box::new(ws),
                    });
                }
                *link = tx;
            }
            spawned
        };
        match spawned {
            Ok(handle) => {
                self.respawned.lock().push(handle);
                // The restart re-shipped shards: cross the conservative
                // invalidation boundary (decodes after this point
                // refactorize each pattern once).
                self.invalidate_decode_caches();
                let ms = started.elapsed().as_secs_f64() * 1e3;
                crate::log_debug!(
                    "cluster",
                    "chaos: restarted worker w({group},{index}) in {ms:.2}ms"
                );
                ms
            }
            Err(e) => {
                // The seat stays dead-flagged off but unservable; the
                // failure detector will age it out.
                crate::log_warn!(
                    "cluster",
                    "chaos: respawn of w({group},{index}) failed: {e}"
                );
                f64::NAN
            }
        }
    }

    fn link_sever(&self, group: usize) {
        self.faults.set_link_dead(group, true);
        crate::log_debug!("cluster", "chaos: severed uplink of group {group}");
    }

    fn link_heal(&self, group: usize) {
        self.faults.set_link_dead(group, false);
        crate::log_debug!("cluster", "chaos: healed uplink of group {group}");
    }

    fn uplink_degrade(&self, group: usize, delay_ms: f64, drop_per_mille: u64) {
        self.faults.set_uplink_degrade(group, delay_ms, drop_per_mille);
        crate::log_debug!(
            "cluster",
            "chaos: degraded uplink of group {group}: +{delay_ms:.1}ms, \
             {drop_per_mille}/1000 loss"
        );
    }
}

/// The serving-time topology for `scheme` under `config`: the scheme's
/// own topology when it matches the config's; otherwise (the flat/grid
/// baselines, which only know code structure) the config's global
/// straggler profiles overlaid onto the scheme's group layout. The
/// in-process launch path and `hiercode node` both derive it from the
/// same config, so worker counts — and therefore the worker/submaster
/// seed stream — cannot drift between the two.
pub(crate) fn serving_topology(
    scheme: &Arc<dyn CodedScheme>,
    config: &ClusterConfig,
) -> crate::scenario::Topology {
    let t = scheme.topology();
    if t == config.code.topology {
        t
    } else {
        crate::scenario::Topology {
            k2: t.k2,
            groups: t
                .groups
                .into_iter()
                .map(|g| crate::scenario::GroupSpec {
                    worker: config.straggler.worker,
                    link: config.straggler.link,
                    ..g
                })
                .collect(),
        }
    }
}

/// One compiled scenario artifact the cluster is (or was) serving,
/// stamped with a monotonically increasing generation number.
struct ActiveArtifact {
    /// 1 at launch, +1 per completed rollout; a rollback returns to
    /// the previous artifact's number.
    generation: u64,
    /// The encoded `.hca` bytes (empty if launch-time compilation was
    /// impossible, e.g. an exotic hand-built config).
    bytes: Vec<u8>,
    /// The decoded config — the classification baseline for the next
    /// rollout.
    config: ClusterConfig,
}

/// Current + previous artifact; `previous` is what [`ClusterCore::rollback`]
/// restores.
struct RolloutState {
    current: ActiveArtifact,
    previous: Option<ActiveArtifact>,
}

/// How long a rollout waits for the batcher to acknowledge its pause.
const PAUSE_GRACE: Duration = Duration::from_secs(5);

/// The owning half of the job service: thread tree + model registry.
pub struct ClusterCore {
    state: Arc<ServiceState>,
    /// Behind a lock so a heavy rollout can swap schemes while client
    /// handles and registrations race.
    scheme: RwLock<Arc<dyn CodedScheme>>,
    backend: ComputeBackend,
    /// Worker seats, fault switchboard and retained shards — the
    /// crash/restart machinery (also the [`FaultInjector`]).
    supervisor: Arc<Supervisor>,
    /// The downstream fan-out to the submasters, retained so rollouts
    /// can broadcast [`SubmasterMsg::Swap`] (the master holds its own
    /// clone).
    transport: Arc<dyn Transport>,
    /// The socket hub when `transport.mode = "socket"`: owns the
    /// listener and per-group connections, doubles as the
    /// [`FaultInjector`] (severs become real teardowns).
    hub: Option<Arc<SocketHub>>,
    threads: Vec<thread::JoinHandle<()>>,
    /// Joined first at shutdown (see `shutdown_inner`): the drain
    /// protocol must not depend on this thread being healthy.
    batcher: Option<thread::JoinHandle<()>>,
    /// Live batching knobs + the rollout pause/resume handshake.
    batcher_ctrl: Arc<BatcherControl>,
    /// Every registered model's original matrix, retained so a heavy
    /// rollout can re-encode under the replacement scheme.
    matrices: Mutex<Vec<(String, ModelId, Arc<Matrix>)>>,
    /// The artifact lineage; also the rollout mutex — at most one
    /// rollout or rollback runs at a time.
    rollout: Mutex<RolloutState>,
    next_model: AtomicU32,
    /// Per-model admission cap applied to registrations; atomic so a
    /// light rollout can retune it.
    queue_cap: AtomicUsize,
}

impl ClusterCore {
    /// Launch the service tree from config alone (no model yet), then
    /// register the config's `serving.models` table.
    pub fn launch(config: &ClusterConfig) -> Result<Self> {
        Self::launch_with_faults(config, FaultConfig::none())
    }

    /// Launch with fault injection (tests / chaos runs).
    pub fn launch_with_faults(config: &ClusterConfig, faults: FaultConfig) -> Result<Self> {
        // Partial-work mode computes per-sub-shard products; the AOT
        // artifact set only covers whole-shard shapes, so gate it to
        // the native backend rather than silently mixing numerics.
        let partial = config.code.topology.groups.iter().any(|g| g.subtasks > 1);
        if config.runtime.use_pjrt && partial {
            return Err(Error::InvalidParams(
                "partial-work mode (subtasks_per_worker > 1) requires the \
                 native backend: sub-shard shapes have no AOT'd PJRT \
                 artifacts yet — set runtime.use_pjrt = false"
                    .into(),
            ));
        }
        // Build via the config so `runtime.decode_threads` reaches every
        // decoder session the master and submasters open.
        let scheme = config.build_scheme()?;
        // Backend.
        let backend = if config.runtime.use_pjrt {
            ComputeBackend::Pjrt(PjrtRuntime::start(config.runtime.artifact_dir.clone())?)
        } else {
            ComputeBackend::Native
        };
        // The scenario layer: per-group worker counts, recovery
        // thresholds, straggler profiles and dead-worker sets all come
        // from the scheme's Topology — the same value the simulator
        // computes E[T] over, so live cluster and analysis can't drift.
        // Schemes that only know code structure (the flat/grid
        // baselines return a default-profile topology) get the global
        // straggler section overlaid onto their group layout.
        let topology = serving_topology(&scheme, config);
        debug_assert_eq!(topology.total_workers(), scheme.num_workers());
        let metrics = Arc::new(Metrics::with_groups(topology.n2()));
        let mut seed_rng = Rng::new(config.seed);
        let (master_tx, master_rx) = mpsc::channel::<MasterMsg>();
        let mut threads = Vec::new();
        let mut submaster_txs = Vec::with_capacity(topology.n2());
        // Launch-time faults become the initial switchboard state; the
        // scenario's per-group dead workers fold in too, so every
        // thread consults one live source of truth.
        let group_sizes = topology.group_sizes();
        let fault_state = Arc::new(FaultState::from_config(&group_sizes, &faults));
        for (g, spec) in topology.groups.iter().enumerate() {
            for &j in &spec.dead_workers {
                fault_state.set_worker_dead(g, j, true);
            }
        }
        // Liveness tracking (config.chaos): heartbeat cadence for every
        // worker/submaster plus the master's failure detector.
        let liveness = if config.chaos.liveness {
            LivenessConfig::new(
                Duration::from_secs_f64(config.chaos.heartbeat_ms / 1e3),
                Duration::from_secs_f64(config.chaos.suspect_ms / 1e3),
                Duration::from_secs_f64(config.chaos.dead_ms / 1e3),
            )
        } else {
            LivenessConfig::disabled()
        };
        let beat = liveness.beat_period();
        let socket_mode = config.transport.mode == TransportMode::Socket;
        let mut seats = Vec::with_capacity(scheme.num_workers());
        let mut group_offsets = Vec::with_capacity(topology.n2());

        if socket_mode {
            // Submaster/worker trees live in `hiercode node` processes
            // and dial in over the hub; this process only records the
            // flat seat layout (the Supervisor keeps zero seats — its
            // crash/restart machinery is vacuous here, the hub maps
            // fault-plan actions onto connections instead).
            let mut off = 0;
            for &sz in &group_sizes {
                group_offsets.push(off);
                off += sz;
            }
        } else {
            for (g, spec) in topology.groups.iter().enumerate() {
                let (sub_tx, sub_rx) = mpsc::channel::<SubmasterMsg>();
                let cancel = Arc::new(crate::coordinator::messages::CancelSet::new());
                // Global scale renders model time as wall-clock; the
                // group's slowdown multiplier is model (the sim applies
                // it too), so they compose.
                let group_scale = config.straggler.scale * spec.slowdown();
                group_offsets.push(seats.len());
                // Workers of this group, with the group's straggler
                // profile.
                let mut group_links = Vec::with_capacity(spec.n1);
                for j in 0..spec.n1 {
                    let (w_tx, w_rx) = mpsc::channel::<WorkerCmd>();
                    let delay = WorkerDelay {
                        model: spec.worker,
                        scale: group_scale,
                        enabled: config.straggler.enabled,
                    };
                    let ctx = WorkerCtx {
                        group: g,
                        index: j,
                        backend: backend.clone(),
                        delay,
                        subtasks: spec.subtasks,
                        cancel: Arc::clone(&cancel),
                        faults: Arc::clone(&fault_state),
                        heartbeat: beat,
                        submaster: sub_tx.clone(),
                    };
                    let seed = seed_rng.next_u64();
                    threads.push(worker::spawn(ctx.clone(), Rng::new(seed), w_rx)?);
                    let link: WorkerLink = Arc::new(RwLock::new(w_tx));
                    group_links.push(Arc::clone(&link));
                    seats.push(Seat { ctx, link, seed });
                }
                let link = LinkDelay {
                    model: spec.link,
                    scale: group_scale,
                    enabled: config.straggler.enabled,
                };
                threads.push(submaster::spawn(
                    g,
                    group_offsets[g],
                    Arc::clone(&scheme),
                    group_links,
                    link,
                    Arc::clone(&fault_state),
                    spec.subtasks,
                    beat,
                    Arc::clone(&cancel),
                    Arc::clone(&metrics),
                    seed_rng.split(),
                    sub_rx,
                    master_tx.clone(),
                )?);
                submaster_txs.push(sub_tx);
            }
        }
        let supervisor = Arc::new(Supervisor {
            seats,
            group_offsets,
            group_sizes,
            faults: fault_state,
            model_shards: Mutex::default(),
            respawned: Mutex::default(),
            generation: AtomicU64::new(0),
            caches: Mutex::new(scheme.decode_caches()),
        });
        let (transport, hub): (Arc<dyn Transport>, Option<Arc<SocketHub>>) = if socket_mode {
            let addr = TransportAddr::parse(&config.transport.listen)?;
            let hub = SocketHub::launch(
                &addr,
                supervisor.group_offsets.clone(),
                supervisor.group_sizes.clone(),
                config.seed,
                Arc::clone(&metrics),
                master_tx.clone(),
            )?;
            // Launch-time dead links become real pre-severed
            // connections (nodes bounce off the handshake until a
            // heal); dead workers live inside node processes the hub
            // cannot reach, so that fault spelling is refused loudly.
            for g in 0..supervisor.group_sizes.len() {
                if supervisor.faults.link_dead(g) {
                    hub.link_sever(g);
                }
            }
            for (g, &n) in supervisor.group_sizes.iter().enumerate() {
                for j in 0..n {
                    if supervisor.faults.worker_dead(g, j) {
                        crate::log_warn!(
                            "cluster",
                            "dead_workers ({g},{j}) ignored in socket mode: \
                             workers live in node processes — kill the node \
                             instead"
                        );
                    }
                }
            }
            (Arc::clone(&hub) as Arc<dyn Transport>, Some(hub))
        } else {
            (
                Arc::new(MemoryTransport::new(submaster_txs)) as Arc<dyn Transport>,
                None,
            )
        };
        threads.push(master::spawn(
            Arc::clone(&scheme),
            Arc::clone(&transport),
            Arc::clone(&metrics),
            Duration::from_secs_f64(config.serving.drain_ms / 1e3),
            liveness,
            Arc::new(WallClock::new()),
            master_rx,
        )?);
        let (req_tx, req_rx) = mpsc::channel::<JobRequest>();
        let (batcher, batcher_ctrl) = batcher::spawn(
            config.batching.clone(),
            Arc::clone(&metrics),
            req_rx,
            master_tx.clone(),
        )?;
        let state = Arc::new(ServiceState {
            models: RwLock::new(HashMap::new()),
            req_tx: RwLock::new(Some(req_tx)),
            master_tx,
            metrics,
            accepting: AtomicBool::new(true),
            next_req: AtomicU64::new(0),
            default_deadline_us: AtomicU64::new(
                (config.serving.default_deadline_ms * 1e3) as u64,
            ),
        });
        // Generation 1 = the launch config itself, compiled to its
        // artifact form so `hiercode admin status` and rollback have a
        // baseline (empty bytes if the config has no artifact
        // spelling — the config copy is authoritative either way).
        let launch_artifact = ActiveArtifact {
            generation: 1,
            bytes: controlplane::compile(config).unwrap_or_default(),
            config: config.clone(),
        };
        state
            .metrics
            .artifact_generation
            .store(1, Ordering::Relaxed);
        let scheme_name = scheme.name();
        let scheme_workers = scheme.num_workers();
        let core = Self {
            state,
            scheme: RwLock::new(scheme),
            backend,
            supervisor,
            transport,
            hub,
            threads,
            batcher: Some(batcher),
            batcher_ctrl,
            matrices: Mutex::default(),
            rollout: Mutex::new(RolloutState {
                current: launch_artifact,
                previous: None,
            }),
            next_model: AtomicU32::new(0),
            queue_cap: AtomicUsize::new(config.serving.queue_cap),
        };
        crate::log_info!(
            "cluster",
            "service up: {} ({} workers in {} groups), backend={}, {} threads, \
             queue cap {}/model",
            scheme_name,
            scheme_workers,
            topology.n2(),
            if config.runtime.use_pjrt { "pjrt" } else { "native" },
            core.threads.len(),
            config.serving.queue_cap
        );
        // The config's model table (synthetic seeded matrices — the
        // serve/loadgen multi-tenant setup in config form).
        for spec in &config.serving.models {
            let mut mr = Rng::new(spec.seed);
            let a = Matrix::from_fn(spec.rows, spec.cols, |_, _| mr.uniform(-1.0, 1.0));
            core.register_model(&spec.name, &a)?;
        }
        Ok(core)
    }

    /// Register a named computation: encode `a`, ship one shard per
    /// worker, and open the model for submissions. Channel FIFO
    /// guarantees the shards precede any job that multiplies them, so
    /// submissions may begin the moment this returns.
    pub fn register_model(&self, name: &str, a: &Matrix) -> Result<()> {
        if name.is_empty() {
            return Err(Error::InvalidParams(
                "model name must be non-empty".into(),
            ));
        }
        let scheme = self.scheme();
        let (m, d) = a.shape();
        let div = scheme.row_divisor();
        if m % div != 0 {
            return Err(Error::InvalidParams(format!(
                "model '{name}': matrix rows {m} not divisible by the {} \
                 scheme's row divisor {div}",
                scheme.name()
            )));
        }
        // Cheap duplicate pre-check — don't pay the encode for an
        // obvious mistake (the authoritative check is below, under the
        // write lock).
        if self.state.models.read().contains_key(name) {
            return Err(Error::InvalidParams(format!(
                "model '{name}' is already registered"
            )));
        }
        // Encode + narrow off-lock: this is the expensive part, and
        // holding the table lock here would stall every concurrent
        // submission (they take the read lock) for its duration.
        let shards = scheme.encode(a)?;
        debug_assert_eq!(shards.len(), scheme.num_workers());
        let shard_shape = (shards[0].rows(), shards[0].cols());
        let supported_widths = self
            .backend
            .supported_batch_widths(shard_shape.0, shard_shape.1);
        if let Some(ws) = &supported_widths {
            if ws.is_empty() {
                return Err(Error::Runtime(format!(
                    "model '{name}': no worker artifact for shard shape {}x{} — \
                     add (r={}, d={}, b=…) to python/compile/aot.py WORKER_SPECS \
                     and re-run `make artifacts`",
                    shard_shape.0, shard_shape.1, shard_shape.0, shard_shape.1
                )));
            }
        }
        let mut worker_shards = Vec::with_capacity(shards.len());
        for shard in &shards {
            worker_shards.push(WorkerShard::new(shard)?);
        }
        // Authoritative duplicate check, shard shipping (cheap channel
        // sends) and table insert under one short write-lock hold, so
        // racing duplicate registrations can't interleave their Loads.
        // The worker channels are unbounded, so the sends below cannot
        // block while the lock is held — allowlisted for the
        // lock-discipline lint.
        let mut models = self.state.models.write();
        if models.contains_key(name) {
            return Err(Error::InvalidParams(format!(
                "model '{name}' is already registered"
            )));
        }
        let id = ModelId(self.next_model.fetch_add(1, Ordering::Relaxed));
        // Retain BEFORE shipping: a concurrent chaos restart either
        // sees this model in its snapshot or the Loads below go through
        // the link it just swapped in (see `Supervisor::retain_model`).
        self.supervisor.retain_model(id, worker_shards.clone());
        // Retain the original matrix too: a heavy rollout re-encodes
        // every model under the replacement scheme.
        self.matrices
            .lock()
            .push((name.to_string(), id, Arc::new(a.clone())));
        // Socket mode: the hub retains the `f64` shard matrices and
        // ships `Load` frames to every connected node, re-shipping on
        // reconnect (the socket analogue of the supervisor's restart
        // re-ship). Done under the same write lock so the frame order
        // preserves the in-memory Load-before-Job guarantee.
        if let Some(hub) = &self.hub {
            hub.retain_and_ship(
                id.0,
                worker_shards.iter().map(|ws| ws.f64.clone()).collect(),
            );
        }
        for (seat, ws) in self.supervisor.seats.iter().zip(worker_shards) {
            // Best-effort per seat: a crashed worker's channel is
            // disconnected, but its shards are retained above and will
            // re-ship when the supervisor restarts it.
            if seat
                .link
                .read()
                .send(WorkerCmd::Load {
                    model: id,
                    shard: Box::new(ws),
                })
                .is_err()
            {
                crate::log_debug!(
                    "cluster",
                    "model {id:?}: shard for crashed worker \
                     w({},{}) deferred to restart",
                    seat.ctx.group,
                    seat.ctx.index
                );
            }
        }
        models.insert(
            name.to_string(),
            Arc::new(ModelEntry::new(
                id,
                name,
                d,
                m,
                self.queue_cap.load(Ordering::Relaxed),
                supported_widths,
            )),
        );
        crate::log_info!(
            "cluster",
            "registered model '{name}' ({m}x{d}) as {id:?}"
        );
        drop(models);
        // Registration shipped fresh shards — same conservative
        // invalidation boundary as a restart's re-ship.
        self.supervisor.invalidate_decode_caches();
        Ok(())
    }

    /// A new client handle (clone freely — one per tenant thread).
    pub fn handle(&self) -> ClientHandle {
        ClientHandle {
            state: Arc::clone(&self.state),
        }
    }

    /// The cluster's current coding scheme (an owned handle — a heavy
    /// rollout may swap the underlying scheme at any time).
    pub fn scheme(&self) -> Arc<dyn CodedScheme> {
        Arc::clone(&*self.scheme.read())
    }

    /// Names of the registered models, sorted.
    pub fn model_names(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.state.models.read().keys().cloned().collect();
        names.sort();
        names
    }

    /// The cluster's [`FaultInjector`] — hand it to
    /// [`crate::coordinator::chaos::spawn`] to replay a fault plan
    /// against this cluster. In-memory clusters inject through the
    /// supervisor's fault switchboard; socket clusters inject through
    /// the hub, where `link_sever` is a real connection teardown.
    pub fn injector(&self) -> Arc<dyn FaultInjector> {
        match &self.hub {
            Some(hub) => Arc::clone(hub) as Arc<dyn FaultInjector>,
            None => Arc::clone(&self.supervisor) as Arc<dyn FaultInjector>,
        }
    }

    /// The socket hub, when this cluster was launched with
    /// `transport.mode = "socket"` (tests / CLI introspection).
    pub fn hub(&self) -> Option<&Arc<SocketHub>> {
        self.hub.as_ref()
    }

    /// Block until every group has a connected node, or `timeout_ms`
    /// elapses. In-memory clusters are always "connected".
    pub fn wait_connected(&self, timeout_ms: u64) -> bool {
        match &self.hub {
            Some(hub) => hub.wait_connected(timeout_ms),
            None => true,
        }
    }

    /// The supervisor itself (fault switchboard access for tests).
    pub fn supervisor(&self) -> &Arc<Supervisor> {
        &self.supervisor
    }

    /// Metrics snapshot, including the per-model admission breakdown
    /// and the scheme's aggregated decode-cache counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.state.metrics.snapshot();
        let cache = self.supervisor.decode_cache_stats();
        snap.decode_cache_hits = cache.hits;
        snap.decode_cache_misses = cache.misses;
        snap.decode_cache_evictions = cache.evictions;
        snap.decode_cache_hit_rate = cache.hit_rate();
        // Per-link transport counters live hub-side (per-connection
        // atomics); overlay them onto the per-group rows here.
        if let Some(hub) = &self.hub {
            for (g, st) in hub.group_stats().iter().enumerate() {
                if let Some(pg) = snap.per_group.get_mut(g) {
                    pg.transport_bytes_sent = st.bytes_sent;
                    pg.transport_bytes_received = st.bytes_received;
                    pg.transport_frames_sent = st.frames_sent;
                    pg.transport_frames_received = st.frames_received;
                    pg.transport_reconnects = st.reconnects;
                }
            }
        }
        let models = self.state.models.read();
        let mut per_model: Vec<ModelMetricsSnapshot> = models
            .values()
            .map(|e| ModelMetricsSnapshot {
                name: e.name.clone(),
                queued: e.admission.queued(),
                accepted: e.accepted.load(Ordering::Relaxed),
                rejected: e.rejected.load(Ordering::Relaxed),
                shed: e.shed.load(Ordering::Relaxed),
                completed: e.completed.load(Ordering::Relaxed),
            })
            .collect();
        per_model.sort_by(|a, b| a.name.cmp(&b.name));
        snap.models = per_model;
        snap
    }

    // ------------------------------------------------------------------
    // Control plane: artifact hot reload
    // ------------------------------------------------------------------

    /// The generation number of the artifact currently being served
    /// (1 = the launch config; +1 per completed rollout).
    pub fn artifact_generation(&self) -> u64 {
        self.rollout.lock().current.generation
    }

    /// Hot-swap to a compiled `.hca` scenario artifact without
    /// dropping in-flight jobs. Light rollouts (model table, serving
    /// limits, batching knobs) apply in place; heavy rollouts (a
    /// changed per-group `k1` plan) re-encode every retained model,
    /// quiesce, cut over, and resume. Incompatible candidates (changed
    /// scheme, `k2`, worker layout, …) reject with
    /// [`Error::Incompatible`] before anything is applied. Returns the
    /// new generation number.
    pub fn load_artifact(&self, bytes: &[u8]) -> Result<u64> {
        let artifact = controlplane::decode(bytes)?;
        let candidate = artifact.config;
        // The rollout lock serializes rollouts and rollbacks end to
        // end, and makes `current.config` a stable classification
        // baseline for the duration.
        let mut ro = self.rollout.lock();
        let kind = controlplane::classify(&ro.current.config, &candidate)?;
        match kind {
            RolloutKind::Light => {
                self.apply_light(&ro.current.config, &candidate)?;
            }
            RolloutKind::Heavy => {
                self.apply_heavy(&ro.current.config, &candidate)?;
                // A heavy artifact may retune knobs and the model
                // table too; reconcile those under the new scheme.
                self.apply_light(&ro.current.config, &candidate)?;
            }
        }
        let generation = ro.current.generation + 1;
        let displaced = std::mem::replace(
            &mut ro.current,
            ActiveArtifact {
                generation,
                bytes: bytes.to_vec(),
                config: candidate,
            },
        );
        ro.previous = Some(displaced);
        Metrics::inc(&self.state.metrics.rollouts);
        self.state
            .metrics
            .artifact_generation
            .store(generation, Ordering::Relaxed);
        crate::log_info!(
            "cluster",
            "rollout complete ({kind:?}): serving artifact generation {generation}"
        );
        Ok(generation)
    }

    /// Restore the previous artifact generation through the same
    /// light/heavy machinery as a rollout. The displaced artifact
    /// becomes the new `previous`, so a rollback can itself be undone.
    /// Returns the restored generation number.
    pub fn rollback(&self) -> Result<u64> {
        let mut ro = self.rollout.lock();
        let prev = match ro.previous.take() {
            Some(p) => p,
            None => {
                return Err(Error::Incompatible(
                    "no previous artifact generation to roll back to".into(),
                ))
            }
        };
        let outcome = (|| {
            match controlplane::classify(&ro.current.config, &prev.config)? {
                RolloutKind::Light => {
                    self.apply_light(&ro.current.config, &prev.config)
                }
                RolloutKind::Heavy => {
                    self.apply_heavy(&ro.current.config, &prev.config)?;
                    self.apply_light(&ro.current.config, &prev.config)
                }
            }
        })();
        if let Err(e) = outcome {
            ro.previous = Some(prev);
            return Err(e);
        }
        let generation = prev.generation;
        let displaced = std::mem::replace(&mut ro.current, prev);
        ro.previous = Some(displaced);
        Metrics::inc(&self.state.metrics.rollbacks);
        self.state
            .metrics
            .artifact_generation
            .store(generation, Ordering::Relaxed);
        crate::log_info!(
            "cluster",
            "rollback complete: serving artifact generation {generation} again"
        );
        Ok(generation)
    }

    /// Compute a re-optimized candidate artifact from live state: the
    /// current config with its per-group `k1` plan re-run through the
    /// allocator, each group's service rate discounted by its
    /// dead-worker fraction (when liveness tracking has swept).
    /// Returns compiled candidate bytes; **nothing is applied** — feed
    /// the bytes back through [`ClusterCore::load_artifact`] to adopt.
    pub fn reoptimize_artifact(&self) -> Result<Vec<u8>> {
        let snap = self.metrics();
        let config = self.rollout.lock().current.config.clone();
        let topo = &config.code.topology;
        if topo.groups.is_empty() {
            return Err(Error::InvalidParams(
                "no groups to re-optimize".into(),
            ));
        }
        let mut n1 = Vec::with_capacity(topo.groups.len());
        let mut mu1 = Vec::with_capacity(topo.groups.len());
        let mut mu2 = Vec::with_capacity(topo.groups.len());
        let mut total_k1 = 0usize;
        for (g, spec) in topo.groups.iter().enumerate() {
            n1.push(spec.n1);
            total_k1 += spec.k1;
            let slow = spec.slowdown().max(1e-9);
            let mut rate1 = 1.0 / (spec.worker.mean() * slow).max(1e-9);
            // Liveness overlay: a group missing workers is effectively
            // slower, so discount its rate by the alive fraction and
            // let the allocator shift recovery burden off it.
            if let Some(alive) =
                snap.per_group.get(g).and_then(|pg| pg.alive_workers)
            {
                if (alive as usize) < spec.n1 && spec.n1 > 0 {
                    rate1 *= (alive as f64 / spec.n1 as f64).max(1e-3);
                }
            }
            mu1.push(rate1);
            mu2.push(1.0 / (spec.link.mean() * slow).max(1e-9));
        }
        let problem = crate::sim::allocate::AllocationProblem {
            n1,
            k2: topo.k2,
            mu1,
            mu2,
            total_k1,
        };
        let alloc = crate::sim::allocate::optimize(&problem)?;
        let mut cand = config.clone();
        for (g, spec) in cand.code.topology.groups.iter_mut().enumerate() {
            spec.k1 = alloc.k1.get(g).copied().unwrap_or(spec.k1);
        }
        if let Some(first) = cand.code.topology.groups.first() {
            cand.code.k1 = first.k1;
        }
        controlplane::compile(&cand)
    }

    /// Apply the live-tunable half of a rollout: serving limits,
    /// batching knobs, and the config-level model table. Synthetic
    /// spec validation runs before any mutation; models registered at
    /// runtime (absent from both spec tables) are left untouched.
    fn apply_light(
        &self,
        current: &ClusterConfig,
        cand: &ClusterConfig,
    ) -> Result<()> {
        let scheme = self.scheme();
        let div = scheme.row_divisor();
        for spec in &cand.serving.models {
            if spec.rows % div != 0 {
                return Err(Error::Incompatible(format!(
                    "model '{}': {} rows not divisible by the {} scheme's \
                     row divisor {div} (nothing applied)",
                    spec.name,
                    spec.rows,
                    scheme.name()
                )));
            }
        }
        // Serving limits: registration default + every live gate.
        self.queue_cap
            .store(cand.serving.queue_cap, Ordering::Relaxed);
        self.state.default_deadline_us.store(
            (cand.serving.default_deadline_ms * 1e3) as u64,
            Ordering::Relaxed,
        );
        for entry in self.state.models.read().values() {
            entry.admission.set_cap(cand.serving.queue_cap);
        }
        // Batching knobs, applied to the running batcher.
        self.batcher_ctrl
            .set_batching(cand.batching.max_batch, cand.batching.max_wait_ms);
        // Model table reconcile. Removals first, then adds/replacements.
        for spec in &current.serving.models {
            if !cand.serving.models.iter().any(|s| s.name == spec.name) {
                self.unregister_model(&spec.name);
            }
        }
        for spec in &cand.serving.models {
            let unchanged = current.serving.models.iter().any(|s| s == spec);
            let registered =
                self.state.models.read().contains_key(&spec.name);
            if unchanged && registered {
                continue;
            }
            if registered {
                self.unregister_model(&spec.name);
            }
            let mut mr = Rng::new(spec.seed);
            let a = Matrix::from_fn(spec.rows, spec.cols, |_, _| {
                mr.uniform(-1.0, 1.0)
            });
            self.register_model(&spec.name, &a)?;
        }
        Ok(())
    }

    /// Apply a heavy rollout (changed per-group `k1` plan): re-encode
    /// every retained model under the replacement scheme, quiesce the
    /// dispatch path, cut over, resume. Every failure before the
    /// cut-over leaves the cluster running the old plan untouched.
    fn apply_heavy(
        &self,
        current: &ClusterConfig,
        cand: &ClusterConfig,
    ) -> Result<()> {
        if self.hub.is_some() {
            return Err(Error::Incompatible(
                "heavy rollout (changed k1 plan) requires the in-memory \
                 transport: socket-mode node processes must relaunch with \
                 the new artifact instead"
                    .into(),
            ));
        }
        if matches!(self.backend, ComputeBackend::Pjrt(_)) {
            return Err(Error::Incompatible(
                "heavy rollout requires the native backend: the re-encoded \
                 shard shapes have no AOT'd PJRT artifacts"
                    .into(),
            ));
        }
        let new_scheme = cand.build_scheme()?;
        let div = new_scheme.row_divisor();
        // Phase 1 — validate and re-encode under the new scheme,
        // before any mutation. The matrices registry snapshot is
        // cheap (Arc clones); encoding is the expensive part and runs
        // entirely off-lock.
        let matrices: Vec<(String, ModelId, Arc<Matrix>)> = self
            .matrices
            .lock()
            .iter()
            .map(|(n, id, a)| (n.clone(), *id, Arc::clone(a)))
            .collect();
        for (name, _, a) in &matrices {
            if a.rows() % div != 0 {
                return Err(Error::Incompatible(format!(
                    "model '{name}': {} rows not divisible by the new \
                     scheme's row divisor {div} (nothing applied)",
                    a.rows()
                )));
            }
        }
        let mut reencoded: Vec<(ModelId, Vec<WorkerShard>)> =
            Vec::with_capacity(matrices.len());
        for (_, id, a) in &matrices {
            let shards = new_scheme.encode(a)?;
            let mut ws = Vec::with_capacity(shards.len());
            for shard in &shards {
                ws.push(WorkerShard::new(shard)?);
            }
            reencoded.push((*id, ws));
        }
        // Phase 2 — quiesce. The batcher pauses (submissions keep
        // being accepted and buffer in its lanes — nothing bounces),
        // then the master acks once its in-flight job count hits zero.
        if !self.batcher_ctrl.pause(PAUSE_GRACE) {
            self.batcher_ctrl.resume();
            return Err(Error::Coordinator(
                "rollout aborted: batcher did not acknowledge the pause \
                 (nothing applied)"
                    .into(),
            ));
        }
        let (ack_tx, ack_rx) = mpsc::channel();
        if self
            .state
            .master_tx
            .send(MasterMsg::Quiesce(ack_tx))
            .is_err()
        {
            self.batcher_ctrl.resume();
            return Err(Error::Coordinator(
                "rollout aborted: master channel closed (nothing applied)"
                    .into(),
            ));
        }
        let grace = Duration::from_secs_f64(current.serving.drain_ms / 1e3);
        if ack_rx.recv_timeout(grace).is_err() {
            self.batcher_ctrl.resume();
            return Err(Error::Coordinator(format!(
                "rollout aborted: in-flight jobs did not drain within \
                 {:.0}ms (nothing applied)",
                current.serving.drain_ms
            )));
        }
        // Phase 3 — cut over on an idle tree. Channel FIFO carries the
        // ordering guarantees: each worker sees its Load before any
        // post-resume Compute, the master sees Reconfigure before any
        // post-resume Batch, and each submaster sees Swap before any
        // post-resume Job. Model entries (ids, dims, admission gates)
        // are untouched — buffered requests stay valid across the
        // swap.
        for (id, ws) in reencoded {
            self.supervisor.replace_model(id, ws.clone());
            for (seat, shard) in self.supervisor.seats.iter().zip(ws) {
                let _ = seat.link.read().send(WorkerCmd::Load {
                    model: id,
                    shard: Box::new(shard),
                });
            }
        }
        let _ = self
            .state
            .master_tx
            .send(MasterMsg::Reconfigure(SchemeSwap(Arc::clone(&new_scheme))));
        for g in 0..self.transport.groups() {
            self.transport
                .send(g, SubmasterMsg::Swap(SchemeSwap(Arc::clone(&new_scheme))));
        }
        self.supervisor
            .set_decode_caches(new_scheme.decode_caches());
        *self.scheme.write() = new_scheme;
        // Phase 4 — resume dispatch: buffered lanes flush under the
        // new plan.
        self.batcher_ctrl.resume();
        crate::log_info!(
            "cluster",
            "heavy rollout cut over: new k1 plan [{}]",
            cand.code
                .topology
                .groups
                .iter()
                .map(|g| g.k1.to_string())
                .collect::<Vec<_>>()
                .join(",")
        );
        Ok(())
    }

    /// Remove a model from the serving table. In-flight requests keep
    /// the entry alive through their `Arc`; retained shards and the
    /// matrix are forgotten so restarts stop re-shipping them.
    fn unregister_model(&self, name: &str) {
        let entry = self.state.models.write().remove(name);
        if let Some(entry) = entry {
            self.supervisor.forget_model(entry.id);
            self.matrices.lock().retain(|(n, _, _)| n.as_str() != name);
            crate::log_info!("cluster", "unregistered model '{name}'");
        }
    }

    /// Graceful shutdown: refuse new submissions, drain queued and
    /// in-flight jobs (reply or fail every accepted request — bounded
    /// by `serving.drain_ms`), stop all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.state.accepting.store(false, Ordering::Release);
        // Taking the sender closes the request channel once in-flight
        // submissions finish; the batcher then flushes its tails and
        // hands the master the drain baton.
        self.state.req_tx.write().take();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
            // Belt and braces: if the batcher died without sending
            // Drain (panic), send it ourselves so the master — whose
            // channel we keep alive through ServiceState — still
            // drains and exits instead of blocking recv() forever.
            // A second Drain is idempotent.
            let _ = self.state.master_tx.send(MasterMsg::Drain);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // Workers respawned by chaos restarts exit the same way (their
        // submaster's Shutdown reaches them through the swapped link).
        for t in self.supervisor.respawned.lock().drain(..) {
            let _ = t.join();
        }
        // Socket mode: master has exited (Shutdown frames went out via
        // the hub's writers), so tearing the hub down now lets remote
        // nodes see EOF and exit their downstream loops.
        if let Some(hub) = &self.hub {
            hub.close();
        }
    }
}

impl Drop for ClusterCore {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// The admin surface: `hiercode admin` talks to a running core through
/// this vtable (see [`controlplane::admin`]).
impl AdminControl for ClusterCore {
    fn status_json(&self) -> String {
        let (generation, rollback_available) = {
            let ro = self.rollout.lock();
            (ro.current.generation, ro.previous.is_some())
        };
        let scheme = self.scheme();
        let names: Vec<String> = self
            .model_names()
            .iter()
            .map(|n| format!("\"{}\"", n.replace('\\', "\\\\").replace('"', "\\\"")))
            .collect();
        format!(
            "{{\"scheme\": \"{}\", \"generation\": {}, \
             \"rollback_available\": {}, \"groups\": {}, \"workers\": {}, \
             \"transport\": \"{}\", \"accepting\": {}, \"models\": [{}]}}",
            scheme.name(),
            generation,
            rollback_available,
            self.transport.groups(),
            scheme.num_workers(),
            if self.hub.is_some() { "socket" } else { "memory" },
            self.state.accepting.load(Ordering::Acquire),
            names.join(", ")
        )
    }

    fn metrics_json(&self) -> String {
        self.metrics().to_json()
    }

    fn reoptimize(&self) -> Result<Vec<u8>> {
        self.reoptimize_artifact()
    }

    fn rollout(&self, artifact: &[u8]) -> Result<u64> {
        self.load_artifact(artifact)
    }

    fn rollback(&self) -> Result<u64> {
        ClusterCore::rollback(self)
    }
}

/// Single-tenant convenience facade: a [`ClusterCore`] serving one
/// matrix registered as [`DEFAULT_MODEL`], with the pre-serving-layer
/// `launch`/`submit` shape. Multi-tenant callers use the core directly.
pub struct Cluster {
    core: ClusterCore,
    client: ClientHandle,
    m: usize,
    d: usize,
}

impl Cluster {
    /// Launch a cluster serving products with `a` (`m × d`), using the
    /// given config and no faults.
    pub fn launch(config: &ClusterConfig, a: &Matrix) -> Result<Self> {
        Self::launch_with_faults(config, a, FaultConfig::none())
    }

    /// Launch with fault injection (tests / chaos runs).
    pub fn launch_with_faults(
        config: &ClusterConfig,
        a: &Matrix,
        faults: FaultConfig,
    ) -> Result<Self> {
        let core = ClusterCore::launch_with_faults(config, faults)?;
        core.register_model(DEFAULT_MODEL, a)?;
        let client = core.handle();
        let (m, d) = a.shape();
        Ok(Self { core, client, m, d })
    }

    /// Submit a request `x` (`d` elements); returns a handle to wait on
    /// for `A·x` (`m` elements).
    pub fn submit(&self, x: Vec<f64>) -> Result<JobHandle> {
        self.client.submit(x)
    }

    /// The owning core (register more models, spawn more handles).
    pub fn core(&self) -> &ClusterCore {
        &self.core
    }

    /// A fresh client handle onto this cluster.
    pub fn handle(&self) -> ClientHandle {
        self.core.handle()
    }

    /// Output dimension `m` of the default model.
    pub fn output_dim(&self) -> usize {
        self.m
    }

    /// Input dimension `d` of the default model.
    pub fn input_dim(&self) -> usize {
        self.d
    }

    /// The cluster's current coding scheme.
    pub fn scheme(&self) -> Arc<dyn CodedScheme> {
        self.core.scheme()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics()
    }

    /// Graceful shutdown: stop accepting requests, drain, stop all
    /// threads.
    pub fn shutdown(self) {
        self.core.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::SchemeKind;
    use crate::linalg::ops;

    fn test_matrix(m: usize, d: usize, seed: u64) -> Matrix {
        let mut r = Rng::new(seed);
        Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn end_to_end_native_single_request() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(8, 4, 1);
        let cluster = Cluster::launch(&config, &a).unwrap();
        let x = vec![1.0, -0.5, 0.25, 2.0];
        let y = cluster.submit(x.clone()).unwrap().wait().unwrap();
        let expect = ops::matvec(&a, &x);
        assert_eq!(y.len(), 8);
        for (i, (&got, &want)) in y.iter().zip(expect.iter()).enumerate() {
            assert!((got - want).abs() < 1e-4, "row {i}: {got} vs {want}");
        }
        let m = cluster.metrics();
        assert_eq!(m.completed, 1);
        cluster.shutdown();
    }

    #[test]
    fn many_requests_batch_and_complete() {
        let config = ClusterConfig::demo(4, 2, 4, 2);
        let a = test_matrix(16, 4, 2);
        let cluster = Cluster::launch(&config, &a).unwrap();
        let mut handles = Vec::new();
        let mut expects = Vec::new();
        for i in 0..20 {
            let mut r = Rng::new(100 + i);
            let x: Vec<f64> = (0..4).map(|_| r.uniform(-1.0, 1.0)).collect();
            expects.push(ops::matvec(&a, &x));
            handles.push(cluster.submit(x).unwrap());
        }
        for (h, expect) in handles.into_iter().zip(expects) {
            let y = h.wait().unwrap();
            for (got, want) in y.iter().zip(expect.iter()) {
                assert!((got - want).abs() < 1e-3);
            }
        }
        let m = cluster.metrics();
        assert_eq!(m.requests, 20);
        assert!(m.jobs <= 20, "batching should fold requests into jobs");
        assert_eq!(m.completed, m.jobs);
        cluster.shutdown();
    }

    #[test]
    fn survives_tolerable_faults() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(8, 4, 3);
        let faults = FaultConfig::none()
            .with_dead_workers(&[(0, 0)]) // group 0 down to exactly k1
            .with_dead_links(&[2]); // group 2 unreachable
        assert!(faults.survivable_for(&config.code.topology));
        let cluster = Cluster::launch_with_faults(&config, &a, faults).unwrap();
        let x = vec![1.0, 1.0, 1.0, 1.0];
        let y = cluster
            .submit(x.clone())
            .unwrap()
            .wait_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        cluster.shutdown();
    }

    #[test]
    fn stalls_cleanly_under_excess_faults_and_cancels() {
        let mut config = ClusterConfig::demo(3, 2, 3, 2);
        // Keep the admission deadline out of the way: this test is
        // about client-side timeout + cancellation.
        config.serving.default_deadline_ms = 60_000.0;
        config.serving.drain_ms = 500.0;
        let a = test_matrix(8, 4, 4);
        let faults = FaultConfig::none().with_dead_links(&[0, 1]);
        assert!(!faults.survivable_for(&config.code.topology));
        let cluster = Cluster::launch_with_faults(&config, &a, faults).unwrap();
        let res = cluster
            .submit(vec![1.0; 4])
            .unwrap()
            .wait_timeout(std::time::Duration::from_millis(500));
        assert!(res.is_err(), "must time out, not return wrong data");
        // The timeout cancelled the abandoned job (no state leak).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if cluster.metrics().cancelled == 1 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "abandoned job was never cancelled: {:?}",
                cluster.metrics()
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        cluster.shutdown();
    }

    /// Tentpole end-to-end: a partial-work cluster (r = 4) serves
    /// correct products while workers stream sub-results and groups
    /// decode from k1·r of them.
    #[test]
    fn partial_work_cluster_end_to_end() {
        let mut config = ClusterConfig::demo(4, 2, 3, 2);
        for g in &mut config.code.topology.groups {
            g.subtasks = 4;
        }
        config.straggler.enabled = true;
        config.straggler.scale = 0.0005;
        // Row divisor is k2·k1·r = 16.
        let a = test_matrix(32, 4, 20);
        let cluster = Cluster::launch(&config, &a).unwrap();
        assert_eq!(cluster.scheme().name(), "hier(4,2)x(3,2)r4");
        let mut handles = Vec::new();
        let mut expects = Vec::new();
        for i in 0..6 {
            let mut r = Rng::new(300 + i);
            let x: Vec<f64> = (0..4).map(|_| r.uniform(-1.0, 1.0)).collect();
            expects.push(ops::matvec(&a, &x));
            handles.push(cluster.submit(x).unwrap());
        }
        for (h, expect) in handles.into_iter().zip(expects) {
            let y = h.wait().unwrap();
            for (got, want) in y.iter().zip(expect.iter()) {
                assert!((got - want).abs() < 1e-3);
            }
        }
        let m = cluster.metrics();
        assert_eq!(m.completed, m.jobs);
        assert!(
            m.group_decodes >= m.jobs * 2,
            "every job needs k2 = 2 group decodes (got {} for {} jobs)",
            m.group_decodes,
            m.jobs
        );
        cluster.shutdown();
    }

    #[test]
    fn partial_work_requires_native_backend() {
        let mut config = ClusterConfig::demo(2, 1, 2, 1);
        config.runtime.use_pjrt = true;
        config.code.topology.groups[0].subtasks = 2;
        assert!(matches!(
            ClusterCore::launch(&config),
            Err(Error::InvalidParams(_))
        ));
    }

    #[test]
    fn wrong_dimension_rejected() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(8, 4, 5);
        let cluster = Cluster::launch(&config, &a).unwrap();
        assert!(cluster.submit(vec![1.0; 5]).is_err());
        cluster.shutdown();
    }

    #[test]
    fn indivisible_matrix_rejected() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(10, 4, 6); // 10 % 4 != 0
        assert!(Cluster::launch(&config, &a).is_err());
    }

    #[test]
    fn straggler_injection_still_correct() {
        // With real exponential delays enabled, answers stay exact.
        let mut config = ClusterConfig::demo(3, 2, 3, 2);
        config.straggler.enabled = true;
        config.straggler.scale = 0.002; // small but nonzero sleeps
        let a = test_matrix(8, 4, 7);
        let cluster = Cluster::launch(&config, &a).unwrap();
        let x = vec![0.5, -1.0, 2.0, 0.0];
        let y = cluster.submit(x.clone()).unwrap().wait().unwrap();
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        let m = cluster.metrics();
        assert!(m.latency_mean > 0.0);
        cluster.shutdown();
    }

    #[test]
    fn flat_scheme_single_request() {
        // A relay-topology scheme through the same cluster facade.
        let config = ClusterConfig::demo_scheme(SchemeKind::Mds, 3, 2, 3, 2);
        let a = test_matrix(8, 4, 8);
        let cluster = Cluster::launch(&config, &a).unwrap();
        assert_eq!(cluster.scheme().name(), "mds(9,4)");
        let x = vec![0.5, 1.5, -0.25, 1.0];
        let y = cluster.submit(x.clone()).unwrap().wait().unwrap();
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        let m = cluster.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.group_decodes, 0, "flat schemes decode at the master only");
        cluster.shutdown();
    }

    #[test]
    fn two_models_serve_concurrently_from_one_core() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let core = ClusterCore::launch(&config).unwrap();
        let a0 = test_matrix(8, 4, 10);
        let a1 = test_matrix(16, 2, 11); // different shape entirely
        core.register_model("alpha", &a0).unwrap();
        core.register_model("beta", &a1).unwrap();
        assert_eq!(core.model_names(), vec!["alpha", "beta"]);
        let client = core.handle();
        assert_eq!(client.model_dims("alpha"), Some((8, 4)));
        assert_eq!(client.model_dims("beta"), Some((16, 2)));
        let x0 = vec![1.0, -1.0, 0.5, 2.0];
        let x1 = vec![0.25, -2.0];
        let h0 = client.submit_to("alpha", x0.clone()).unwrap();
        let h1 = client.submit_to("beta", x1.clone()).unwrap();
        let y0 = h0.wait().unwrap();
        let y1 = h1.wait().unwrap();
        let e0 = ops::matvec(&a0, &x0);
        let e1 = ops::matvec(&a1, &x1);
        assert_eq!(y0.len(), 8);
        assert_eq!(y1.len(), 16);
        for (got, want) in y0.iter().zip(e0.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        for (got, want) in y1.iter().zip(e1.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        let m = core.metrics();
        assert_eq!(m.models.len(), 2);
        assert_eq!(m.models[0].name, "alpha");
        assert_eq!(m.models[0].completed, 1);
        assert_eq!(m.models[1].completed, 1);
        core.shutdown();
    }

    #[test]
    fn duplicate_and_unknown_models_rejected() {
        let config = ClusterConfig::demo(2, 1, 2, 1);
        let core = ClusterCore::launch(&config).unwrap();
        let a = test_matrix(2, 3, 12);
        core.register_model("m", &a).unwrap();
        assert!(core.register_model("m", &a).is_err(), "duplicate name");
        assert!(core.register_model("", &a).is_err(), "empty name");
        let client = core.handle();
        assert!(matches!(
            client.submit_to("ghost", vec![1.0; 3]),
            Err(Error::InvalidParams(_))
        ));
        core.shutdown();
    }

    #[test]
    fn busy_backpressure_at_queue_cap() {
        let mut config = ClusterConfig::demo(2, 1, 2, 1);
        config.serving.queue_cap = 2;
        // A wide-open batch window so submissions pile up in the queue.
        config.batching.max_batch = 1024;
        config.batching.max_wait_ms = 200.0;
        let core = ClusterCore::launch(&config).unwrap();
        core.register_model("m", &test_matrix(2, 2, 13)).unwrap();
        let client = core.handle();
        let h0 = client.submit_to("m", vec![1.0, 2.0]).unwrap();
        let h1 = client.submit_to("m", vec![3.0, 4.0]).unwrap();
        // Third submission exceeds the cap → explicit backpressure.
        let err = client.submit_to("m", vec![5.0, 6.0]).unwrap_err();
        assert!(matches!(err, Error::Busy { ref model } if model == "m"));
        // The queue drains; accepted work completes.
        assert!(h0.wait().is_ok());
        assert!(h1.wait().is_ok());
        let m = core.metrics();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.requests, 2);
        // After dispatch the queue slot is free again.
        assert!(client.submit_to("m", vec![7.0, 8.0]).unwrap().wait().is_ok());
        core.shutdown();
    }

    #[test]
    fn try_wait_polls_and_handle_crosses_threads() {
        let config = ClusterConfig::demo(2, 1, 2, 1);
        let core = ClusterCore::launch(&config).unwrap();
        core.register_model("m", &test_matrix(4, 2, 14)).unwrap();
        let client = core.handle();
        let handle = client.submit_to("m", vec![1.0, -1.0]).unwrap();
        // Poll from another thread (JobHandle is Send).
        let waiter = std::thread::spawn(move || loop {
            if let Some(outcome) = handle.try_wait() {
                return outcome;
            }
            std::thread::sleep(Duration::from_millis(1));
        });
        let y = waiter.join().unwrap().unwrap();
        assert_eq!(y.len(), 4);
        core.shutdown();
    }

    #[test]
    fn submissions_after_shutdown_refused() {
        let config = ClusterConfig::demo(2, 1, 2, 1);
        let core = ClusterCore::launch(&config).unwrap();
        core.register_model("m", &test_matrix(2, 2, 15)).unwrap();
        let client = core.handle();
        core.shutdown();
        assert!(client.submit_to("m", vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn light_rollout_retunes_knobs_and_model_table() {
        use crate::config::schema::ModelSpec;
        let mut config = ClusterConfig::demo(3, 2, 3, 2);
        config.serving.models.push(ModelSpec {
            name: "alpha".into(),
            rows: 8,
            cols: 4,
            seed: 7,
        });
        let core = ClusterCore::launch(&config).unwrap();
        assert_eq!(core.model_names(), vec!["alpha"]);
        assert_eq!(core.artifact_generation(), 1);
        let mut cand = config.clone();
        cand.serving.queue_cap = 128;
        cand.batching.max_batch = 7;
        cand.serving.models.clear();
        cand.serving.models.push(ModelSpec {
            name: "beta".into(),
            rows: 16,
            cols: 2,
            seed: 9,
        });
        let bytes = crate::controlplane::compile(&cand).unwrap();
        assert_eq!(core.load_artifact(&bytes).unwrap(), 2);
        assert_eq!(core.model_names(), vec!["beta"]);
        let client = core.handle();
        assert!(client
            .submit_to("beta", vec![0.5, -1.0])
            .unwrap()
            .wait()
            .is_ok());
        assert!(client.submit_to("alpha", vec![1.0; 4]).is_err());
        // Rollback restores generation 1 and the old table.
        assert_eq!(core.rollback().unwrap(), 1);
        assert_eq!(core.artifact_generation(), 1);
        assert_eq!(core.model_names(), vec!["alpha"]);
        assert!(client
            .submit_to("alpha", vec![1.0; 4])
            .unwrap()
            .wait()
            .is_ok());
        let m = core.metrics();
        assert_eq!(m.rollouts, 1);
        assert_eq!(m.rollbacks, 1);
        assert_eq!(m.artifact_generation, 1);
        core.shutdown();
    }

    #[test]
    fn heavy_rollout_swaps_k1_plan_without_dropping_jobs() {
        let config = ClusterConfig::demo(4, 2, 3, 2);
        let core = ClusterCore::launch(&config).unwrap();
        // Rows divisible by the old divisor (4) and the new plan's
        // lcm(2·3, 2·2, 2·1) = 12.
        let a = test_matrix(24, 4, 40);
        core.register_model("m", &a).unwrap();
        let client = core.handle();
        let mut handles = Vec::new();
        let mut expects = Vec::new();
        for i in 0..8 {
            let mut r = Rng::new(500 + i);
            let x: Vec<f64> = (0..4).map(|_| r.uniform(-1.0, 1.0)).collect();
            expects.push(ops::matvec(&a, &x));
            handles.push(client.submit_to("m", x).unwrap());
        }
        let mut cand = config.clone();
        let plan = [3usize, 2, 1];
        for (g, spec) in cand.code.topology.groups.iter_mut().enumerate() {
            spec.k1 = plan[g];
        }
        cand.code.k1 = plan[0];
        let bytes = crate::controlplane::compile(&cand).unwrap();
        assert_eq!(core.load_artifact(&bytes).unwrap(), 2);
        // Every pre-swap job completes with the right answer.
        for (h, expect) in handles.into_iter().zip(expects) {
            let y = h.wait().unwrap();
            for (got, want) in y.iter().zip(expect.iter()) {
                assert!((got - want).abs() < 1e-3);
            }
        }
        // Post-swap submissions decode under the new plan.
        let x = vec![1.0, -0.5, 0.25, 2.0];
        let y = client.submit_to("m", x.clone()).unwrap().wait().unwrap();
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-3);
        }
        let m = core.metrics();
        assert_eq!(m.rollouts, 1);
        assert_eq!(m.artifact_generation, 2);
        core.shutdown();
    }

    #[test]
    fn incompatible_rollout_rejected_atomically() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let core = ClusterCore::launch(&config).unwrap();
        core.register_model("m", &test_matrix(8, 4, 41)).unwrap();
        // Changed outer code dimension: structurally incompatible.
        let mut cand = config.clone();
        cand.code.k2 = 3;
        cand.code.topology.k2 = 3;
        let bytes = crate::controlplane::compile(&cand).unwrap();
        assert!(matches!(
            core.load_artifact(&bytes),
            Err(Error::Incompatible(_))
        ));
        // Nothing applied: same generation, still serving.
        assert_eq!(core.artifact_generation(), 1);
        let client = core.handle();
        assert!(client.submit_to("m", vec![1.0; 4]).unwrap().wait().is_ok());
        assert!(matches!(core.rollback(), Err(Error::Incompatible(_))));
        core.shutdown();
    }

    #[test]
    fn corrupt_artifact_rejected() {
        let config = ClusterConfig::demo(2, 1, 2, 1);
        let core = ClusterCore::launch(&config).unwrap();
        let mut bytes = crate::controlplane::compile(&config).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        assert!(core.load_artifact(&bytes).is_err());
        assert_eq!(core.artifact_generation(), 1);
        core.shutdown();
    }
}
