//! The public facade: launch a cluster around a matrix `A` with any
//! coding scheme, submit requests, collect results, read metrics, shut
//! down cleanly.
//!
//! The cluster is generic over [`CodedScheme`]: `config.code.scheme`
//! selects `hierarchical | mds | product | replication | polynomial`,
//! and the same master/submaster/worker topology serves all of them —
//! schemes with splittable decodes (hierarchical) decode inside the
//! submasters, the rest relay raw products to the master's streaming
//! decode session.

use crate::coding::CodedScheme;
use crate::coordinator::backend::{ComputeBackend, WorkerShard};
use crate::coordinator::batcher;
use crate::coordinator::fault::FaultConfig;
use crate::coordinator::master;
use crate::coordinator::messages::{JobRequest, MasterMsg, RequestId, SubmasterMsg, WorkerCmd};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::submaster::{self, LinkDelay};
use crate::coordinator::worker::{self, WorkerDelay};
use crate::config::schema::ClusterConfig;
use crate::linalg::Matrix;
use crate::runtime::PjrtRuntime;
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

/// Handle to one in-flight request.
pub struct JobHandle {
    rx: mpsc::Receiver<std::result::Result<Vec<f64>, String>>,
    master: mpsc::Sender<MasterMsg>,
    req_id: RequestId,
}

impl JobHandle {
    /// Block until the result arrives.
    pub fn wait(self) -> Result<Vec<f64>> {
        match self.rx.recv() {
            Ok(Ok(y)) => Ok(y),
            Ok(Err(msg)) => Err(Error::Coordinator(msg)),
            Err(_) => Err(Error::Coordinator(
                "cluster shut down before replying".into(),
            )),
        }
    }

    /// Block with a timeout. On timeout the request is **cancelled**:
    /// the master drops its reply route and, once no client waits on
    /// the underlying job, cancels the job itself — so abandoned jobs
    /// leak neither decode work nor master-side state.
    pub fn wait_timeout(self, timeout: std::time::Duration) -> Result<Vec<f64>> {
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(y)) => Ok(y),
            Ok(Err(msg)) => Err(Error::Coordinator(msg)),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let _ = self.master.send(MasterMsg::CancelRequest(self.req_id));
                Err(Error::Coordinator("request timed out".into()))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(Error::Coordinator(
                "cluster shut down before replying".into(),
            )),
        }
    }

    /// Abandon the request without waiting.
    pub fn cancel(self) {
        let _ = self.master.send(MasterMsg::CancelRequest(self.req_id));
    }
}

/// A running coded-computation cluster.
pub struct Cluster {
    req_tx: Option<mpsc::Sender<JobRequest>>,
    master_tx: mpsc::Sender<MasterMsg>,
    metrics: Arc<Metrics>,
    threads: Vec<thread::JoinHandle<()>>,
    d: usize,
    m: usize,
    scheme: Arc<dyn CodedScheme>,
    next_req: AtomicU64,
}

impl Cluster {
    /// Launch a cluster serving products with `a` (`m × d`), using the
    /// given config and no faults.
    pub fn launch(config: &ClusterConfig, a: &Matrix) -> Result<Self> {
        Self::launch_with_faults(config, a, FaultConfig::none())
    }

    /// Launch with fault injection (tests / chaos runs).
    pub fn launch_with_faults(
        config: &ClusterConfig,
        a: &Matrix,
        faults: FaultConfig,
    ) -> Result<Self> {
        // Build via the config so `runtime.decode_threads` reaches every
        // decoder session the master and submasters open.
        let scheme = config.build_scheme()?;
        let (m, d) = a.shape();
        let div = scheme.row_divisor();
        if m % div != 0 {
            return Err(Error::InvalidParams(format!(
                "matrix rows {m} not divisible by the {} scheme's row divisor {div}",
                scheme.name()
            )));
        }
        // Backend.
        let backend = if config.runtime.use_pjrt {
            ComputeBackend::Pjrt(PjrtRuntime::start(config.runtime.artifact_dir.clone())?)
        } else {
            ComputeBackend::Native
        };
        // Encode A (setup path, f64) and narrow shards for the workers.
        let shards = scheme.encode(a)?;
        debug_assert_eq!(shards.len(), scheme.num_workers());
        let shard_shape = (shards[0].rows(), shards[0].cols());
        let supported_widths =
            backend.supported_batch_widths(shard_shape.0, shard_shape.1);
        if let Some(ws) = &supported_widths {
            if ws.is_empty() {
                return Err(Error::Runtime(format!(
                    "no worker artifact for shard shape {}x{} — \
                     add (r={}, d={}, b=…) to python/compile/aot.py WORKER_SPECS \
                     and re-run `make artifacts`",
                    shard_shape.0, shard_shape.1, shard_shape.0, shard_shape.1
                )));
            }
        }

        // The scenario layer: per-group worker counts, recovery
        // thresholds, straggler profiles and dead-worker sets all come
        // from the scheme's Topology — the same value the simulator
        // computes E[T] over, so live cluster and analysis can't drift.
        // Schemes that only know code structure (the flat/grid
        // baselines return a default-profile topology) get the global
        // straggler section overlaid onto their group layout.
        let topology = {
            let t = scheme.topology();
            if t == config.code.topology {
                t
            } else {
                crate::scenario::Topology {
                    k2: t.k2,
                    groups: t
                        .groups
                        .into_iter()
                        .map(|g| crate::scenario::GroupSpec {
                            worker: config.straggler.worker,
                            link: config.straggler.link,
                            ..g
                        })
                        .collect(),
                }
            }
        };
        debug_assert_eq!(topology.total_workers(), scheme.num_workers());
        let metrics = Arc::new(Metrics::with_groups(topology.n2()));
        let mut seed_rng = Rng::new(config.seed);
        let (master_tx, master_rx) = mpsc::channel::<MasterMsg>();
        let mut threads = Vec::new();
        let mut submaster_txs = Vec::with_capacity(topology.n2());

        let mut offset = 0usize;
        for (g, spec) in topology.groups.iter().enumerate() {
            let (sub_tx, sub_rx) = mpsc::channel::<SubmasterMsg>();
            let cancel = Arc::new(crate::coordinator::messages::CancelSet::new());
            // Global scale renders model time as wall-clock; the
            // group's slowdown multiplier is model (the sim applies it
            // too), so they compose.
            let group_scale = config.straggler.scale * spec.slowdown();
            // Workers of this group, with the group's straggler profile.
            let mut worker_txs = Vec::with_capacity(spec.n1);
            for j in 0..spec.n1 {
                let shard = &shards[offset + j];
                let (w_tx, w_rx) = mpsc::channel::<WorkerCmd>();
                let delay = WorkerDelay {
                    model: spec.worker,
                    scale: group_scale,
                    enabled: config.straggler.enabled,
                };
                let dead = faults.worker_dead(g, j) || spec.dead_workers.contains(&j);
                threads.push(worker::spawn(
                    g,
                    j,
                    WorkerShard::new(shard)?,
                    backend.clone(),
                    delay,
                    dead,
                    Arc::clone(&cancel),
                    seed_rng.split(),
                    w_rx,
                    sub_tx.clone(),
                ));
                worker_txs.push(w_tx);
            }
            let link = LinkDelay {
                model: spec.link,
                scale: group_scale,
                enabled: config.straggler.enabled,
            };
            threads.push(submaster::spawn(
                g,
                offset,
                Arc::clone(&scheme),
                m,
                worker_txs,
                link,
                faults.link_dead(g),
                Arc::clone(&cancel),
                Arc::clone(&metrics),
                seed_rng.split(),
                sub_rx,
                master_tx.clone(),
            ));
            submaster_txs.push(sub_tx);
            offset += spec.n1;
        }
        threads.push(master::spawn(
            Arc::clone(&scheme),
            submaster_txs,
            m,
            Arc::clone(&metrics),
            master_rx,
        ));
        let (req_tx, req_rx) = mpsc::channel::<JobRequest>();
        threads.push(batcher::spawn(
            d,
            config.batching.clone(),
            supported_widths,
            Arc::clone(&metrics),
            req_rx,
            master_tx.clone(),
        ));
        crate::log_info!(
            "cluster",
            "launched {} ({} workers in {} groups) over {}x{} matrix, backend={}, {} threads",
            scheme.name(),
            scheme.num_workers(),
            topology.n2(),
            m,
            d,
            if config.runtime.use_pjrt { "pjrt" } else { "native" },
            threads.len()
        );
        Ok(Self {
            req_tx: Some(req_tx),
            master_tx,
            metrics,
            threads,
            d,
            m,
            scheme,
            next_req: AtomicU64::new(0),
        })
    }

    /// Submit a request `x` (`d` elements); returns a handle to wait on
    /// for `A·x` (`m` elements).
    pub fn submit(&self, x: Vec<f64>) -> Result<JobHandle> {
        if x.len() != self.d {
            return Err(Error::InvalidParams(format!(
                "request dimension {} != cluster dimension {}",
                x.len(),
                self.d
            )));
        }
        let req_id = RequestId(self.next_req.fetch_add(1, Ordering::Relaxed));
        let (reply, rx) = mpsc::channel();
        self.req_tx
            .as_ref()
            .expect("cluster running")
            .send(JobRequest {
                x,
                reply,
                submitted_at: std::time::Instant::now(),
                req_id,
            })
            .map_err(|_| Error::Coordinator("cluster is shutting down".into()))?;
        Ok(JobHandle {
            rx,
            master: self.master_tx.clone(),
            req_id,
        })
    }

    /// Output dimension `m`.
    pub fn output_dim(&self) -> usize {
        self.m
    }

    /// Input dimension `d`.
    pub fn input_dim(&self) -> usize {
        self.d
    }

    /// The cluster's coding scheme.
    pub fn scheme(&self) -> &Arc<dyn CodedScheme> {
        &self.scheme
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Graceful shutdown: stop accepting requests, stop all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        // Closing the request channel stops the batcher.
        self.req_tx.take();
        let _ = self.master_tx.send(MasterMsg::Shutdown);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::SchemeKind;
    use crate::linalg::ops;

    fn test_matrix(m: usize, d: usize, seed: u64) -> Matrix {
        let mut r = Rng::new(seed);
        Matrix::from_fn(m, d, |_, _| r.uniform(-1.0, 1.0))
    }

    #[test]
    fn end_to_end_native_single_request() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(8, 4, 1);
        let cluster = Cluster::launch(&config, &a).unwrap();
        let x = vec![1.0, -0.5, 0.25, 2.0];
        let y = cluster.submit(x.clone()).unwrap().wait().unwrap();
        let expect = ops::matvec(&a, &x);
        assert_eq!(y.len(), 8);
        for (i, (&got, &want)) in y.iter().zip(expect.iter()).enumerate() {
            assert!((got - want).abs() < 1e-4, "row {i}: {got} vs {want}");
        }
        let m = cluster.metrics();
        assert_eq!(m.completed, 1);
        cluster.shutdown();
    }

    #[test]
    fn many_requests_batch_and_complete() {
        let config = ClusterConfig::demo(4, 2, 4, 2);
        let a = test_matrix(16, 4, 2);
        let cluster = Cluster::launch(&config, &a).unwrap();
        let mut handles = Vec::new();
        let mut expects = Vec::new();
        for i in 0..20 {
            let mut r = Rng::new(100 + i);
            let x: Vec<f64> = (0..4).map(|_| r.uniform(-1.0, 1.0)).collect();
            expects.push(ops::matvec(&a, &x));
            handles.push(cluster.submit(x).unwrap());
        }
        for (h, expect) in handles.into_iter().zip(expects) {
            let y = h.wait().unwrap();
            for (got, want) in y.iter().zip(expect.iter()) {
                assert!((got - want).abs() < 1e-3);
            }
        }
        let m = cluster.metrics();
        assert_eq!(m.requests, 20);
        assert!(m.jobs <= 20, "batching should fold requests into jobs");
        assert_eq!(m.completed, m.jobs);
        cluster.shutdown();
    }

    #[test]
    fn survives_tolerable_faults() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(8, 4, 3);
        let faults = FaultConfig::none()
            .with_dead_workers(&[(0, 0)]) // group 0 down to exactly k1
            .with_dead_links(&[2]); // group 2 unreachable
        assert!(faults.survivable(3, 2, 3, 2));
        let cluster = Cluster::launch_with_faults(&config, &a, faults).unwrap();
        let x = vec![1.0, 1.0, 1.0, 1.0];
        let y = cluster
            .submit(x.clone())
            .unwrap()
            .wait_timeout(std::time::Duration::from_secs(30))
            .unwrap();
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        cluster.shutdown();
    }

    #[test]
    fn stalls_cleanly_under_excess_faults_and_cancels() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(8, 4, 4);
        let faults = FaultConfig::none().with_dead_links(&[0, 1]);
        assert!(!faults.survivable(3, 2, 3, 2));
        let cluster = Cluster::launch_with_faults(&config, &a, faults).unwrap();
        let res = cluster
            .submit(vec![1.0; 4])
            .unwrap()
            .wait_timeout(std::time::Duration::from_millis(500));
        assert!(res.is_err(), "must time out, not return wrong data");
        // The timeout cancelled the abandoned job (no state leak).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if cluster.metrics().cancelled == 1 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "abandoned job was never cancelled: {:?}",
                cluster.metrics()
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        cluster.shutdown();
    }

    #[test]
    fn wrong_dimension_rejected() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(8, 4, 5);
        let cluster = Cluster::launch(&config, &a).unwrap();
        assert!(cluster.submit(vec![1.0; 5]).is_err());
        cluster.shutdown();
    }

    #[test]
    fn indivisible_matrix_rejected() {
        let config = ClusterConfig::demo(3, 2, 3, 2);
        let a = test_matrix(10, 4, 6); // 10 % 4 != 0
        assert!(Cluster::launch(&config, &a).is_err());
    }

    #[test]
    fn straggler_injection_still_correct() {
        // With real exponential delays enabled, answers stay exact.
        let mut config = ClusterConfig::demo(3, 2, 3, 2);
        config.straggler.enabled = true;
        config.straggler.scale = 0.002; // small but nonzero sleeps
        let a = test_matrix(8, 4, 7);
        let cluster = Cluster::launch(&config, &a).unwrap();
        let x = vec![0.5, -1.0, 2.0, 0.0];
        let y = cluster.submit(x.clone()).unwrap().wait().unwrap();
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        let m = cluster.metrics();
        assert!(m.latency_mean > 0.0);
        cluster.shutdown();
    }

    #[test]
    fn flat_scheme_single_request() {
        // A relay-topology scheme through the same cluster facade.
        let config = ClusterConfig::demo_scheme(SchemeKind::Mds, 3, 2, 3, 2);
        let a = test_matrix(8, 4, 8);
        let cluster = Cluster::launch(&config, &a).unwrap();
        assert_eq!(cluster.scheme().name(), "mds(9,4)");
        let x = vec![0.5, 1.5, -0.25, 1.0];
        let y = cluster.submit(x.clone()).unwrap().wait().unwrap();
        let expect = ops::matvec(&a, &x);
        for (got, want) in y.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-4);
        }
        let m = cluster.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.group_decodes, 0, "flat schemes decode at the master only");
        cluster.shutdown();
    }
}
