//! Submaster thread: the group leader of Fig. 1.
//!
//! Forwards job broadcasts to its workers, collects their products, and
//! — the moment the `k1`-th product for a job arrives — performs the
//! **intra-group decode** (recovering `Ã_i·X`) and ships it to the
//! master after a ToR-link delay. Products arriving after the decode
//! are counted and discarded (the paper's "fastest `k1`" semantics).
//! Because every group's submaster is its own thread, the `n2` decodes
//! of §IV run genuinely in parallel.

use crate::coding::HierarchicalCode;
use crate::coordinator::messages::{
    CancelSet, GroupResult, JobBroadcast, JobId, SubmasterMsg, WorkerCmd,
};
use crate::coordinator::metrics::Metrics;
use crate::linalg::Matrix;
use crate::sim::straggler::StragglerModel;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Uplink (ToR) delay settings.
#[derive(Clone)]
pub struct LinkDelay {
    /// Delay distribution (the paper's `Exp(µ2)`).
    pub model: StragglerModel,
    /// Wall-clock seconds per model time unit.
    pub scale: f64,
    /// Master switch.
    pub enabled: bool,
}

struct JobState {
    /// Collected `(worker index, product)` pairs.
    results: Vec<(usize, Matrix)>,
    /// Set once decoded and shipped.
    decoded: bool,
}

/// Spawn the submaster for `group`.
#[allow(clippy::too_many_arguments)]
pub fn spawn(
    group: usize,
    code: Arc<HierarchicalCode>,
    workers: Vec<mpsc::Sender<WorkerCmd>>,
    link: LinkDelay,
    link_dead: bool,
    cancel: Arc<CancelSet>,
    metrics: Arc<Metrics>,
    mut rng: Rng,
    rx: mpsc::Receiver<SubmasterMsg>,
    master: mpsc::Sender<crate::coordinator::messages::MasterMsg>,
) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name(format!("hiercode-sm{group}"))
        .spawn(move || {
            let k1 = code.params().k1[group];
            let mut jobs: HashMap<JobId, JobState> = HashMap::new();
            while let Ok(msg) = rx.recv() {
                match msg {
                    SubmasterMsg::Shutdown => {
                        for w in &workers {
                            let _ = w.send(WorkerCmd::Shutdown);
                        }
                        break;
                    }
                    SubmasterMsg::Job(job) => {
                        jobs.insert(
                            job.id,
                            JobState {
                                results: Vec::with_capacity(k1),
                                decoded: false,
                            },
                        );
                        for w in &workers {
                            let _ = w.send(WorkerCmd::Compute(JobBroadcast {
                                id: job.id,
                                x: Arc::clone(&job.x),
                            }));
                        }
                    }
                    SubmasterMsg::Done(done) => {
                        Metrics::inc(&metrics.worker_products);
                        let Some(state) = jobs.get_mut(&done.id) else {
                            // Job already completed and garbage-collected.
                            Metrics::inc(&metrics.late_products);
                            continue;
                        };
                        if state.decoded {
                            Metrics::inc(&metrics.late_products);
                            continue;
                        }
                        state.results.push((done.index, done.data));
                        if state.results.len() < k1 {
                            continue;
                        }
                        // k1-th fastest arrived: cancel the group's
                        // still-running workers, then decode.
                        state.decoded = true;
                        cancel.mark(done.id);
                        match code.decode_group(group, &state.results) {
                            Ok((data, flops)) => {
                                Metrics::inc(&metrics.group_decodes);
                                Metrics::add(&metrics.decode_flops, flops);
                                let finished_at = Instant::now();
                                if link_dead {
                                    crate::log_debug!(
                                        "submaster",
                                        "group {group}: uplink dead, dropping job {:?}",
                                        done.id
                                    );
                                } else {
                                    if link.enabled {
                                        let d = link.model.sample(&mut rng) * link.scale;
                                        if d > 0.0 {
                                            thread::sleep(Duration::from_secs_f64(d));
                                        }
                                    }
                                    let _ = master.send(
                                        crate::coordinator::messages::MasterMsg::Group(
                                            GroupResult {
                                                id: done.id,
                                                group,
                                                data,
                                                decode_flops: flops,
                                                finished_at,
                                            },
                                        ),
                                    );
                                }
                                // Keep the entry (decoded=true) so later
                                // arrivals count as late; trim memory.
                                let state = jobs.get_mut(&done.id).expect("state exists");
                                state.results.clear();
                                state.results.shrink_to_fit();
                            }
                            Err(e) => {
                                crate::log_error!(
                                    "submaster",
                                    "group {group} decode failed for job {:?}: {e}",
                                    done.id
                                );
                            }
                        }
                    }
                }
            }
        })
        .expect("failed to spawn submaster thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{MasterMsg, WorkerDone};
    use crate::linalg::ops;
    use crate::util::rng::Rng as URng;

    fn no_link_delay() -> LinkDelay {
        LinkDelay {
            model: StragglerModel::Deterministic { value: 0.0 },
            scale: 0.0,
            enabled: false,
        }
    }

    /// Drive a submaster directly with synthetic worker results and
    /// check it decodes at the k1-th arrival.
    #[test]
    fn decodes_at_k1th_result_and_discards_late() {
        let code = Arc::new(HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap());
        let mut r = URng::new(4);
        let a = Matrix::from_fn(8, 3, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 1, |_, _| r.uniform(-1.0, 1.0));
        let grouped = code.encode_grouped(&a).unwrap();
        let group = 1usize;
        // Products of group 1's three workers.
        let products: Vec<Matrix> = grouped[group]
            .iter()
            .map(|s| ops::matmul(s, &x))
            .collect();

        let (sub_tx, sub_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let h = spawn(
            group,
            Arc::clone(&code),
            vec![], // no real workers; we inject Done messages
            no_link_delay(),
            false,
            Arc::new(CancelSet::new()),
            Arc::clone(&metrics),
            URng::new(5),
            sub_rx,
            master_tx,
        );
        let id = JobId(1);
        sub_tx
            .send(SubmasterMsg::Job(JobBroadcast {
                id,
                x: Arc::new(x.clone()),
            }))
            .unwrap();
        // Worker 2 then worker 0 arrive (k1 = 2) — parity + systematic.
        for &j in &[2usize, 0usize] {
            sub_tx
                .send(SubmasterMsg::Done(WorkerDone {
                    id,
                    index: j,
                    data: products[j].clone(),
                }))
                .unwrap();
        }
        let msg = master_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let MasterMsg::Group(gr) = msg else {
            panic!("expected group result")
        };
        assert_eq!(gr.group, group);
        // Ã_1 · x — check against direct computation.
        let tilde = Matrix::vstack(&[grouped[group][0].clone(), grouped[group][1].clone()])
            .unwrap();
        // grouped[group][0..2] are the systematic shards == Ã_i split.
        let expect = ops::matmul(&tilde, &x);
        assert!(gr.data.max_abs_diff(&expect) < 1e-4);
        // Late third worker is discarded.
        sub_tx
            .send(SubmasterMsg::Done(WorkerDone {
                id,
                index: 1,
                data: products[1].clone(),
            }))
            .unwrap();
        // Shutdown (drains the queue first).
        sub_tx.send(SubmasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.group_decodes, 1);
        assert_eq!(s.late_products, 1);
        assert_eq!(s.worker_products, 3);
    }

    #[test]
    fn dead_link_decodes_but_never_delivers() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let mut r = URng::new(6);
        let a = Matrix::from_fn(2, 2, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(2, 1, |_, _| r.uniform(-1.0, 1.0));
        let grouped = code.encode_grouped(&a).unwrap();
        let (sub_tx, sub_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let h = spawn(
            0,
            code,
            vec![],
            no_link_delay(),
            true, // dead link
            Arc::new(CancelSet::new()),
            Arc::clone(&metrics),
            URng::new(7),
            sub_rx,
            master_tx,
        );
        let id = JobId(2);
        sub_tx
            .send(SubmasterMsg::Job(JobBroadcast {
                id,
                x: Arc::new(x.clone()),
            }))
            .unwrap();
        sub_tx
            .send(SubmasterMsg::Done(WorkerDone {
                id,
                index: 0,
                data: ops::matmul(&grouped[0][0], &x),
            }))
            .unwrap();
        assert!(master_rx.recv_timeout(Duration::from_millis(300)).is_err());
        sub_tx.send(SubmasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        assert_eq!(metrics.snapshot().group_decodes, 1);
    }
}
