//! Submaster thread: the group leader of Fig. 1, scheme-generic.
//!
//! Forwards job broadcasts to its workers and then behaves according to
//! the scheme ([`CodedScheme::group_decoder`]):
//!
//! * **Decoding group** (hierarchical): worker products feed a
//!   per-job streaming [`Decoder`] session; the moment the session
//!   reports `Ready` — the `k1`-th product — the submaster finishes it
//!   (the intra-group decode), cancels the group's still-running
//!   workers and ships the group partial to the master after a ToR-link
//!   delay. Because every group's submaster is its own thread, the `n2`
//!   decodes of §IV run genuinely in parallel.
//! * **Relay group** (mds / product / replication / polynomial —
//!   schemes whose decode cannot be split): every product is forwarded
//!   raw to the master, translated to its flat worker index; the master
//!   session does all decoding.
//!
//! Products arriving after the group decoded — or after the master
//! declared the job finished ([`SubmasterMsg::Finish`]) — are counted
//! and discarded (the paper's "fastest `k1`" semantics).

use crate::coding::{CodedScheme, DecodeProgress, Decoder};
use crate::coordinator::fault::FaultState;
use crate::coordinator::messages::{
    CancelSet, JobId, MasterMsg, PartialResult, SubmasterMsg, WorkerCmd, WorkerLink,
};
use crate::coordinator::metrics::Metrics;
use crate::sim::straggler::StragglerModel;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Uplink (ToR) delay settings.
#[derive(Clone)]
pub struct LinkDelay {
    /// Delay distribution (the paper's `Exp(µ2)`).
    pub model: StragglerModel,
    /// Wall-clock seconds per model time unit.
    pub scale: f64,
    /// Master switch.
    pub enabled: bool,
}

enum GroupJob {
    /// This group's streaming decode session (hierarchical inner code,
    /// sub-result granularity in partial-work mode).
    Decoding {
        /// The session; consumes sub-result indices `j·r + s`.
        session: Box<dyn Decoder>,
        /// Sub-results contributed per in-group worker so far — the
        /// ledger behind the per-group `partials_used` metric: at
        /// decode time, contributions from workers that had NOT
        /// finished all `r` sub-tasks are exactly the straggler
        /// partial work the paper's scheme would have discarded.
        contrib: HashMap<usize, usize>,
    },
    /// No group decoding — forward raw products to the master.
    Relay,
    /// Decoded / shipped / finished — later products are late.
    Done,
}

/// `Done` tombstones only make late products recognizable; unbounded
/// they are a per-job leak in a long-running service. Evicting one
/// turns a late product into an unknown-job drop — the same outcome
/// (both arms count `late_products`) — so past the bound keep only
/// live jobs. Mirrors the master's identical GC.
const DONE_JOBS_BOUND: usize = 8192;

fn gc_done_jobs(jobs: &mut HashMap<JobId, GroupJob>) {
    if jobs.len() > DONE_JOBS_BOUND {
        jobs.retain(|_, s| !matches!(s, GroupJob::Done));
    }
}

/// Ship one partial upstream through the group's (possibly faulted)
/// uplink: dropped outright when severed, dropped with the injected
/// loss probability when degraded, then delayed by the configured ToR
/// model plus any injected extra delay (uniform in `[0, ceiling)` —
/// bounded jitter), and finally sent.
fn ship_partial(
    faults: &FaultState,
    group: usize,
    link: &LinkDelay,
    rng: &mut Rng,
    master: &mpsc::Sender<MasterMsg>,
    pr: PartialResult,
) {
    if faults.link_dead(group) {
        crate::log_debug!(
            "submaster",
            "group {group}: uplink dead, dropping job {:?}",
            pr.id
        );
        return;
    }
    let dpm = faults.uplink_drop_per_mille(group);
    if dpm > 0 && rng.uniform(0.0, 1000.0) < dpm as f64 {
        faults.record_dropped();
        return;
    }
    if link.enabled {
        let d = link.model.sample(rng) * link.scale;
        if d > 0.0 {
            thread::sleep(Duration::from_secs_f64(d));
        }
    }
    let extra_ms = faults.uplink_delay_ms(group);
    if extra_ms > 0.0 {
        thread::sleep(Duration::from_secs_f64(rng.uniform(0.0, extra_ms) / 1e3));
    }
    let _ = master.send(MasterMsg::Partial(pr));
}

/// Spawn the submaster for `group`, whose workers start at flat index
/// `offset`. Output sizing is per-job ([`JobBroadcast::out_rows`]):
/// different models route different heights through the same group.
/// `subtasks` is the group's `r`: worker uploads `(j, s)` feed the
/// decode session as sub-result index `j·r + s` (the identity when
/// `r = 1`). Errors only if the OS refuses to spawn the thread.
///
/// [`JobBroadcast::out_rows`]: crate::coordinator::messages::JobBroadcast::out_rows
#[allow(clippy::too_many_arguments)]
pub fn spawn(
    group: usize,
    offset: usize,
    scheme: Arc<dyn CodedScheme>,
    workers: Vec<WorkerLink>,
    link: LinkDelay,
    faults: Arc<FaultState>,
    subtasks: usize,
    heartbeat: Option<Duration>,
    cancel: Arc<CancelSet>,
    metrics: Arc<Metrics>,
    mut rng: Rng,
    rx: mpsc::Receiver<SubmasterMsg>,
    master: mpsc::Sender<MasterMsg>,
) -> crate::Result<thread::JoinHandle<()>> {
    let handle = thread::Builder::new()
        .name(format!("hiercode-sm{group}"))
        .spawn(move || {
            // Group decodes below run on the runtime-selected SIMD
            // kernels; surface the choice once per submaster so thread
            // dumps and logs tie per-group decode time to a kernel set.
            crate::log_debug!(
                "submaster",
                "group {group} decode kernels: {}",
                crate::linalg::dispatch::active_name()
            );
            // Hot reload swaps the decode scheme between jobs
            // ([`SubmasterMsg::Swap`], sent only while quiesced).
            let mut scheme = scheme;
            let mut jobs: HashMap<JobId, GroupJob> = HashMap::new();
            // Announce liveness immediately (a severed uplink drops it,
            // which is the point: silence IS the failure signal).
            if heartbeat.is_some() && !faults.link_dead(group) {
                let _ = master.send(MasterMsg::Heartbeat {
                    group,
                    worker: None,
                });
            }
            let mut last_beat = Instant::now();
            loop {
                let msg = match heartbeat {
                    Some(period) => match rx.recv_timeout(period) {
                        Ok(m) => m,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if !faults.link_dead(group) {
                                let _ = master.send(MasterMsg::Heartbeat {
                                    group,
                                    worker: None,
                                });
                            }
                            last_beat = Instant::now();
                            continue;
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    },
                    None => match rx.recv() {
                        Ok(m) => m,
                        Err(_) => break,
                    },
                };
                match msg {
                    SubmasterMsg::Shutdown => {
                        for w in &workers {
                            let _ = w.read().send(WorkerCmd::Shutdown);
                        }
                        break;
                    }
                    SubmasterMsg::Swap(swap) => {
                        // Quiesced when sent: no live decode session
                        // consumes products under the old inner code.
                        scheme = swap.0;
                        crate::log_debug!(
                            "submaster",
                            "group {group}: swapped to scheme '{}'",
                            scheme.name()
                        );
                    }
                    SubmasterMsg::Heartbeat(j) => {
                        // Relay the worker's beacon while our uplink is
                        // alive; a severed link silences the whole
                        // group's beacon stream.
                        if !faults.link_dead(group) {
                            let _ = master.send(MasterMsg::Heartbeat {
                                group,
                                worker: Some(j),
                            });
                        }
                    }
                    SubmasterMsg::Job(job) => {
                        let state =
                            match scheme.group_decoder(group, job.out_rows, job.x.cols()) {
                                Some(session) => GroupJob::Decoding {
                                    session,
                                    contrib: HashMap::new(),
                                },
                                None => GroupJob::Relay,
                            };
                        jobs.insert(job.id, state);
                        gc_done_jobs(&mut jobs);
                        for w in &workers {
                            let _ = w.read().send(WorkerCmd::Compute(job.clone()));
                        }
                    }
                    SubmasterMsg::Finish(id) => {
                        // Master completed or cancelled the job: stop any
                        // still-pending worker computes, mark late.
                        cancel.mark(id);
                        if let Some(state) = jobs.get_mut(&id) {
                            *state = GroupJob::Done;
                        } else {
                            jobs.insert(id, GroupJob::Done);
                            gc_done_jobs(&mut jobs);
                        }
                    }
                    SubmasterMsg::Done(done) => {
                        Metrics::inc(&metrics.worker_products);
                        metrics.record_group_product(group);
                        let Some(state) = jobs.get_mut(&done.id) else {
                            // Job unknown (already garbage-collected).
                            Metrics::inc(&metrics.late_products);
                            continue;
                        };
                        match state {
                            GroupJob::Done => {
                                Metrics::inc(&metrics.late_products);
                            }
                            GroupJob::Relay => {
                                ship_partial(
                                    &faults,
                                    group,
                                    &link,
                                    &mut rng,
                                    &master,
                                    PartialResult {
                                        id: done.id,
                                        shard: offset + done.index,
                                        data: done.data,
                                        decoded: false,
                                        decode_flops: 0,
                                        finished_at: Instant::now(),
                                    },
                                );
                            }
                            GroupJob::Decoding { session, contrib } => {
                                // Partial-work: the session's index
                                // space is sub-results, j·r + s (the
                                // identity when r = 1).
                                let pushed = session.push(crate::coding::WorkerResult {
                                    shard: done.index * subtasks + done.subtask,
                                    data: done.data,
                                });
                                if pushed.is_ok() {
                                    *contrib.entry(done.index).or_insert(0) += 1;
                                }
                                match pushed {
                                    Ok(DecodeProgress::NeedMore { .. }) => {}
                                    Ok(DecodeProgress::Ready) => {
                                        // The k1·r-th fastest sub-result
                                        // arrived: cancel the group's
                                        // still-running workers, then run
                                        // the intra-group decode.
                                        cancel.mark(done.id);
                                        // Straggler partial work the
                                        // all-or-nothing scheme would have
                                        // discarded: sub-results from
                                        // workers that never finished all
                                        // r sub-tasks.
                                        let partials: usize = contrib
                                            .values()
                                            .filter(|&&c| c < subtasks)
                                            .sum();
                                        match session.finish() {
                                            Ok(out) => {
                                                Metrics::inc(&metrics.group_decodes);
                                                metrics.record_group_decode(
                                                    group,
                                                    out.seconds,
                                                );
                                                metrics.record_group_partials(
                                                    group,
                                                    partials as u64,
                                                );
                                                Metrics::add(
                                                    &metrics.decode_flops,
                                                    out.flops,
                                                );
                                                let finished_at = Instant::now();
                                                ship_partial(
                                                    &faults,
                                                    group,
                                                    &link,
                                                    &mut rng,
                                                    &master,
                                                    PartialResult {
                                                        id: done.id,
                                                        shard: group,
                                                        data: out.result,
                                                        decoded: true,
                                                        decode_flops: out.flops,
                                                        finished_at,
                                                    },
                                                );
                                                *state = GroupJob::Done;
                                            }
                                            Err(e) => {
                                                crate::log_error!(
                                                    "submaster",
                                                    "group {group} decode failed \
                                                     for job {:?}: {e}",
                                                    done.id
                                                );
                                                *state = GroupJob::Done;
                                            }
                                        }
                                    }
                                    Err(e) => {
                                        crate::log_error!(
                                            "submaster",
                                            "group {group} rejected a product \
                                             for job {:?}: {e}",
                                            done.id
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
                // A busy submaster never hits the recv timeout, so
                // also beat after handling work once the cadence
                // elapsed.
                if let Some(period) = heartbeat {
                    if last_beat.elapsed() >= period {
                        if !faults.link_dead(group) {
                            let _ = master.send(MasterMsg::Heartbeat {
                                group,
                                worker: None,
                            });
                        }
                        last_beat = Instant::now();
                    }
                }
            }
        })?;
    Ok(handle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::HierarchicalCode;
    use crate::coordinator::messages::{JobBroadcast, ModelId, WorkerDone};
    use crate::linalg::{ops, Matrix};
    use crate::util::rng::Rng as URng;

    fn no_link_delay() -> LinkDelay {
        LinkDelay {
            model: StragglerModel::Deterministic { value: 0.0 },
            scale: 0.0,
            enabled: false,
        }
    }

    /// All-healthy fault switchboard big enough for every test group.
    fn healthy_faults() -> Arc<FaultState> {
        Arc::new(FaultState::new(&[4, 4, 4]))
    }

    /// Drive a submaster directly with synthetic worker results and
    /// check it decodes at the k1-th arrival.
    #[test]
    fn decodes_at_k1th_result_and_discards_late() {
        let code = Arc::new(HierarchicalCode::homogeneous(3, 2, 3, 2).unwrap());
        let mut r = URng::new(4);
        let a = Matrix::from_fn(8, 3, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 1, |_, _| r.uniform(-1.0, 1.0));
        let grouped = code.encode_grouped(&a).unwrap();
        let group = 1usize;
        // Products of group 1's three workers.
        let products: Vec<Matrix> = grouped[group]
            .iter()
            .map(|s| ops::matmul(s, &x))
            .collect();

        let (sub_tx, sub_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = Arc::clone(&code);
        let h = spawn(
            group,
            3, // offset of group 1 in the flat indexing
            scheme,
            vec![], // no real workers; we inject Done messages
            no_link_delay(),
            healthy_faults(),
            1,
            None,
            Arc::new(CancelSet::new()),
            Arc::clone(&metrics),
            URng::new(5),
            sub_rx,
            master_tx,
        )
        .expect("spawn submaster");
        let id = JobId(1);
        sub_tx
            .send(SubmasterMsg::Job(JobBroadcast {
                id,
                model: ModelId(0),
                out_rows: 8,
                x: Arc::new(x.clone()),
            }))
            .unwrap();
        // Worker 2 then worker 0 arrive (k1 = 2) — parity + systematic.
        for &j in &[2usize, 0usize] {
            sub_tx
                .send(SubmasterMsg::Done(WorkerDone {
                    id,
                    index: j,
                    subtask: 0,
                    data: products[j].clone(),
                }))
                .unwrap();
        }
        let msg = master_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let MasterMsg::Partial(pr) = msg else {
            panic!("expected group partial")
        };
        assert_eq!(pr.shard, group, "hierarchical partials carry the group index");
        // Ã_1 · x — check against direct computation.
        let tilde = Matrix::vstack(&[grouped[group][0].clone(), grouped[group][1].clone()])
            .unwrap();
        // grouped[group][0..2] are the systematic shards == Ã_i split.
        let expect = ops::matmul(&tilde, &x);
        assert!(pr.data.max_abs_diff(&expect) < 1e-4);
        // Late third worker is discarded.
        sub_tx
            .send(SubmasterMsg::Done(WorkerDone {
                id,
                index: 1,
                subtask: 0,
                data: products[1].clone(),
            }))
            .unwrap();
        // Shutdown (drains the queue first).
        sub_tx.send(SubmasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.group_decodes, 1);
        assert_eq!(s.late_products, 1);
        assert_eq!(s.worker_products, 3);
    }

    /// Partial-work: a group of 4 workers with r = 2 decodes at the
    /// k1·r = 4th sub-result — harvested from one complete worker plus
    /// two stragglers — and records the straggler sub-results in the
    /// per-group `partials_used` metric.
    #[test]
    fn partial_group_decodes_from_straggler_subresults() {
        use crate::scenario::Topology;
        let mut topo = Topology::homogeneous(4, 2, 2, 1);
        for g in &mut topo.groups {
            g.subtasks = 2;
        }
        let code = Arc::new(HierarchicalCode::from_topology(topo).unwrap());
        let r = 2usize;
        let mut rng = URng::new(10);
        let rows = code.required_row_divisor(); // k2·k1·r = 4
        let a = Matrix::from_fn(rows, 3, |_, _| rng.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(3, 1, |_, _| rng.uniform(-1.0, 1.0));
        let grouped = code.encode_grouped(&a).unwrap();
        let group = 0usize;
        // Sub-product of worker j's sub-task s in group 0.
        let sub = |j: usize, s: usize| {
            let shards = grouped[group][j].split_rows(r).unwrap();
            ops::matmul(&shards[s], &x)
        };
        let (sub_tx, sub_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::with_groups(2));
        let scheme: Arc<dyn CodedScheme> = Arc::clone(&code);
        let h = spawn(
            group,
            0,
            scheme,
            vec![],
            no_link_delay(),
            healthy_faults(),
            r,
            None,
            Arc::new(CancelSet::new()),
            Arc::clone(&metrics),
            URng::new(11),
            sub_rx,
            master_tx,
        )
        .expect("spawn submaster");
        let id = JobId(7);
        sub_tx
            .send(SubmasterMsg::Job(JobBroadcast {
                id,
                model: ModelId(0),
                out_rows: rows,
                x: Arc::new(x.clone()),
            }))
            .unwrap();
        // Worker 3 completes both sub-tasks; stragglers 0 and 2 deliver
        // one sub-result each → 4 = k1·r total, 2 from partial workers.
        for (j, s) in [(3usize, 0usize), (3, 1), (0, 0), (2, 0)] {
            sub_tx
                .send(SubmasterMsg::Done(WorkerDone {
                    id,
                    index: j,
                    subtask: s,
                    data: sub(j, s),
                }))
                .unwrap();
        }
        let MasterMsg::Partial(pr) =
            master_rx.recv_timeout(Duration::from_secs(5)).unwrap()
        else {
            panic!("expected group partial")
        };
        assert_eq!(pr.shard, group);
        // Ã_0·x: the k1·r systematic sub-shards (= workers 0 and 1)
        // stack to Ã_0.
        let tilde = Matrix::vstack(&grouped[group][..2]).unwrap();
        let expect = ops::matmul(&tilde, &x);
        assert!(pr.data.max_abs_diff(&expect) < 1e-4);
        assert!(pr.decode_flops > 0, "parity sub-results force a real solve");
        sub_tx.send(SubmasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.group_decodes, 1);
        assert_eq!(
            s.per_group[0].partials_used, 2,
            "two sub-results came from workers that never finished"
        );
    }

    #[test]
    fn dead_link_decodes_but_never_delivers() {
        let code = Arc::new(HierarchicalCode::homogeneous(2, 1, 2, 1).unwrap());
        let mut r = URng::new(6);
        let a = Matrix::from_fn(2, 2, |_, _| r.uniform(-1.0, 1.0));
        let x = Matrix::from_fn(2, 1, |_, _| r.uniform(-1.0, 1.0));
        let grouped = code.encode_grouped(&a).unwrap();
        let (sub_tx, sub_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let scheme: Arc<dyn CodedScheme> = code;
        let faults = healthy_faults();
        faults.set_link_dead(0, true);
        let h = spawn(
            0,
            0,
            scheme,
            vec![],
            no_link_delay(),
            faults,
            1,
            None,
            Arc::new(CancelSet::new()),
            Arc::clone(&metrics),
            URng::new(7),
            sub_rx,
            master_tx,
        )
        .expect("spawn submaster");
        let id = JobId(2);
        sub_tx
            .send(SubmasterMsg::Job(JobBroadcast {
                id,
                model: ModelId(0),
                out_rows: 2,
                x: Arc::new(x.clone()),
            }))
            .unwrap();
        sub_tx
            .send(SubmasterMsg::Done(WorkerDone {
                id,
                index: 0,
                subtask: 0,
                data: ops::matmul(&grouped[0][0], &x),
            }))
            .unwrap();
        assert!(master_rx.recv_timeout(Duration::from_millis(300)).is_err());
        sub_tx.send(SubmasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        assert_eq!(metrics.snapshot().group_decodes, 1);
    }

    /// A relay submaster (flat scheme) forwards raw products translated
    /// to flat worker indices, and drops them after Finish.
    #[test]
    fn relay_group_forwards_flat_indexed_products() {
        use crate::coding::MdsCode;
        let scheme: Arc<dyn CodedScheme> = Arc::new(MdsCode::new(6, 3).unwrap());
        let (sub_tx, sub_rx) = mpsc::channel();
        let (master_tx, master_rx) = mpsc::channel();
        let metrics = Arc::new(Metrics::new());
        let h = spawn(
            0,
            0, // single relay group at offset 0
            scheme,
            vec![],
            no_link_delay(),
            healthy_faults(),
            1,
            None,
            Arc::new(CancelSet::new()),
            Arc::clone(&metrics),
            URng::new(8),
            sub_rx,
            master_tx,
        )
        .expect("spawn submaster");
        let id = JobId(3);
        sub_tx
            .send(SubmasterMsg::Job(JobBroadcast {
                id,
                model: ModelId(0),
                out_rows: 6,
                x: Arc::new(Matrix::identity(2)),
            }))
            .unwrap();
        sub_tx
            .send(SubmasterMsg::Done(WorkerDone {
                id,
                index: 4,
                subtask: 0,
                data: Matrix::zeros(2, 2),
            }))
            .unwrap();
        let MasterMsg::Partial(pr) =
            master_rx.recv_timeout(Duration::from_secs(5)).unwrap()
        else {
            panic!("expected relayed partial")
        };
        assert_eq!(pr.shard, 4);
        assert_eq!(pr.decode_flops, 0);
        // After Finish, further products are late.
        sub_tx.send(SubmasterMsg::Finish(id)).unwrap();
        sub_tx
            .send(SubmasterMsg::Done(WorkerDone {
                id,
                index: 5,
                subtask: 0,
                data: Matrix::zeros(2, 2),
            }))
            .unwrap();
        assert!(master_rx.recv_timeout(Duration::from_millis(200)).is_err());
        sub_tx.send(SubmasterMsg::Shutdown).unwrap();
        h.join().unwrap();
        let s = metrics.snapshot();
        assert_eq!(s.late_products, 1);
        assert_eq!(s.group_decodes, 0, "relay groups never decode");
    }
}
