//! Micro-benchmark harness (offline substitute for `criterion`).
//!
//! `cargo bench` targets in `rust/benches/` use `harness = false` and
//! drive this module: warmup, timed iterations, mean/σ/p50/p95, and a
//! stable one-line-per-benchmark report that EXPERIMENTS.md quotes.
//! Supports `--filter <substr>`, `--iters N`, `--warmup N`, `--csv`.

use crate::util::stats::percentile;
use std::time::Instant;

/// Parsed `cargo bench` CLI options.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    /// Substring filter on benchmark names.
    pub filter: Option<String>,
    /// Timed iterations per benchmark.
    pub iters: usize,
    /// Warmup iterations per benchmark.
    pub warmup: usize,
    /// Emit CSV instead of human-readable rows.
    pub csv: bool,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            filter: None,
            iters: 30,
            warmup: 3,
            csv: false,
        }
    }
}

impl BenchOpts {
    /// Parse from `std::env::args` (skips the libtest `--bench` flag
    /// cargo passes through).
    pub fn from_args() -> Self {
        let mut opts = Self::default();
        let args: Vec<String> = std::env::args().skip(1).collect();
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--filter" if i + 1 < args.len() => {
                    opts.filter = Some(args[i + 1].clone());
                    i += 1;
                }
                "--iters" if i + 1 < args.len() => {
                    opts.iters = args[i + 1].parse().unwrap_or(opts.iters);
                    i += 1;
                }
                "--warmup" if i + 1 < args.len() => {
                    opts.warmup = args[i + 1].parse().unwrap_or(opts.warmup);
                    i += 1;
                }
                "--csv" => opts.csv = true,
                "--bench" => {} // injected by cargo
                other => {
                    // bare positional = filter (criterion compatibility)
                    if !other.starts_with('-') {
                        opts.filter = Some(other.to_string());
                    }
                }
            }
            i += 1;
        }
        opts
    }
}

/// One benchmark's timing summary, in seconds.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name as reported.
    pub name: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: f64,
    /// Standard deviation.
    pub std_dev: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Fastest iteration.
    pub min: f64,
}

/// Format seconds adaptively (ns/µs/ms/s).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:8.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:8.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:8.3}ms", s * 1e3)
    } else {
        format!("{:8.4}s ", s)
    }
}

/// A bench suite: register closures with [`Suite::bench`], then
/// [`Suite::finish`] prints the report.
pub struct Suite {
    opts: BenchOpts,
    results: Vec<BenchResult>,
    header_printed: bool,
}

impl Suite {
    /// Create a suite named `title` using CLI options.
    pub fn new(title: &str) -> Self {
        let opts = BenchOpts::from_args();
        if !opts.csv {
            eprintln!("## bench suite: {title} (iters={}, warmup={})", opts.iters, opts.warmup);
        }
        Self {
            opts,
            results: Vec::new(),
            header_printed: false,
        }
    }

    /// Override iteration counts (for expensive end-to-end benches).
    pub fn with_iters(mut self, iters: usize, warmup: usize) -> Self {
        // CLI-provided values still win.
        let defaults = BenchOpts::default();
        if self.opts.iters == defaults.iters {
            self.opts.iters = iters;
        }
        if self.opts.warmup == defaults.warmup {
            self.opts.warmup = warmup;
        }
        self
    }

    /// Whether `name` passes the CLI filter.
    pub fn selected(&self, name: &str) -> bool {
        self.opts
            .filter
            .as_deref()
            .map(|f| name.contains(f))
            .unwrap_or(true)
    }

    /// Run `f` repeatedly and record its timing. The closure's return
    /// value is passed through `std::hint::black_box` to defeat DCE.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) {
        if !self.selected(name) {
            return;
        }
        for _ in 0..self.opts.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.opts.iters);
        for _ in 0..self.opts.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / samples.len().max(1) as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean,
            std_dev: var.sqrt(),
            p50: percentile(&samples, 0.5),
            p95: percentile(&samples, 0.95),
            min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        };
        self.report(&result);
        self.results.push(result);
    }

    fn report(&mut self, r: &BenchResult) {
        if self.opts.csv {
            if !self.header_printed {
                println!("name,iters,mean_s,std_s,p50_s,p95_s,min_s");
                self.header_printed = true;
            }
            println!(
                "{},{},{:.9},{:.9},{:.9},{:.9},{:.9}",
                r.name, r.iters, r.mean, r.std_dev, r.p50, r.p95, r.min
            );
        } else {
            println!(
                "bench {:<44} mean {} ± {}  p50 {}  p95 {}",
                r.name,
                fmt_time(r.mean),
                fmt_time(r.std_dev),
                fmt_time(r.p50),
                fmt_time(r.p95),
            );
        }
    }

    /// Consume the suite; returns all results for programmatic use.
    pub fn finish(self) -> Vec<BenchResult> {
        self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains('s'));
    }

    #[test]
    fn suite_runs_and_records() {
        let mut s = Suite {
            opts: BenchOpts {
                filter: None,
                iters: 5,
                warmup: 1,
                csv: true,
            },
            results: Vec::new(),
            header_printed: true,
        };
        let mut calls = 0u32;
        s.bench("noop", || {
            calls += 1;
            calls
        });
        let rs = s.finish();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].iters, 5);
        assert_eq!(calls, 6); // warmup + iters
        assert!(rs[0].mean >= 0.0);
    }

    #[test]
    fn filter_skips() {
        let mut s = Suite {
            opts: BenchOpts {
                filter: Some("match".into()),
                iters: 2,
                warmup: 0,
                csv: true,
            },
            results: Vec::new(),
            header_printed: true,
        };
        s.bench("nomatch-here-actually-matches", || 1);
        s.bench("other", || 2);
        assert_eq!(s.finish().len(), 1);
    }
}
