//! Shared artifact-integrity helpers: CRC-32, version gates and
//! checksum verification.
//!
//! Two artifact formats live in this tree — the PJRT AOT manifest
//! (`runtime::artifact`) and the compiled scenario artifact
//! (`controlplane::artifact`) — plus the socket wire format
//! (`transport::wire`). All three must agree on integrity-check
//! semantics: the same CRC-32 (IEEE 802.3) polynomial, the same
//! "reject version skew explicitly" rule, the same "checksum mismatch
//! is a typed error, never a panic" contract. Centralizing the
//! helpers here keeps the formats from drifting.

/// CRC-32 (IEEE 802.3, polynomial `0xEDB88320`) lookup table, built at
/// compile time so the codecs stay allocation- and dependency-free.
const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Gate a format version: `got` must equal `want`, otherwise a typed
/// `Error::Config` naming the artifact (`what`) and both versions —
/// version skew is always rejected explicitly, never coerced.
pub fn check_version(what: &str, got: u64, want: u64) -> crate::Result<()> {
    if got != want {
        return Err(crate::Error::Config(format!(
            "unsupported {what} version {got} (this build speaks {want})"
        )));
    }
    Ok(())
}

/// Verify a section checksum: `data` must hash to `want`, otherwise a
/// typed `Error::Config` naming the artifact section (`what`).
pub fn verify_checksum(what: &str, data: &[u8], want: u32) -> crate::Result<()> {
    let got = crc32(data);
    if got != want {
        return Err(crate::Error::Config(format!(
            "{what}: checksum mismatch (stored {want:#010x}, computed {got:#010x})"
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn version_gate_names_both_versions() {
        assert!(check_version("scenario artifact", 1, 1).is_ok());
        let err = check_version("scenario artifact", 2, 1).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("scenario artifact"), "{msg}");
        assert!(msg.contains('2') && msg.contains('1'), "{msg}");
    }

    #[test]
    fn checksum_gate_is_a_typed_error() {
        let data = b"payload";
        assert!(verify_checksum("section", data, crc32(data)).is_ok());
        let err = verify_checksum("section", data, 0xDEAD_BEEF).unwrap_err();
        assert!(matches!(err, crate::Error::Config(_)));
        assert!(format!("{err}").contains("checksum mismatch"));
    }
}
