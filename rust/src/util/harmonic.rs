//! Harmonic numbers and exponential order statistics.
//!
//! §III of the paper builds every closed-form latency expression out of
//! harmonic numbers: the expected value of the k-th order statistic of
//! `n` i.i.d. `Exp(mu)` variables is `(H_n - H_{n-k}) / mu`.

/// The `n`-th harmonic number `H_n = sum_{l=1}^{n} 1/l`, with `H_0 = 0`
/// (the paper's convention).
///
/// Exact summation for small `n`; for very large `n` an asymptotic
/// expansion is used to keep this O(1) inside tight simulation loops.
pub fn harmonic(n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    if n <= 10_000 {
        // Sum smallest-first for accuracy.
        (1..=n).rev().map(|l| 1.0 / l as f64).sum()
    } else {
        // H_n ≈ ln n + γ + 1/(2n) − 1/(12n²) + 1/(120n⁴)
        let nf = n as f64;
        nf.ln() + EULER_GAMMA + 1.0 / (2.0 * nf) - 1.0 / (12.0 * nf * nf)
            + 1.0 / (120.0 * nf.powi(4))
    }
}

/// Euler–Mascheroni constant γ.
pub const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;

/// Expected value of the `k`-th order statistic (k-th smallest) of `n`
/// i.i.d. `Exp(mu)` random variables: `(H_n − H_{n−k}) / mu`.
///
/// This is the paper's workhorse: e.g. the expected time for the
/// `k1`-th fastest worker of a group of `n1`, or the `k2`-th fastest
/// group-to-master link out of `n2`.
pub fn expected_kth_of_n_exponential(k: usize, n: usize, mu: f64) -> f64 {
    assert!(k <= n, "order statistic k={k} out of n={n}");
    assert!(mu > 0.0, "rate must be positive");
    (harmonic(n) - harmonic(n - k)) / mu
}

/// Variance of the `k`-th order statistic of `n` i.i.d. `Exp(mu)`:
/// `sum_{l=n-k+1}^{n} 1/(l² mu²)` (spacings are independent
/// exponentials by Rényi's representation).
pub fn variance_kth_of_n_exponential(k: usize, n: usize, mu: f64) -> f64 {
    assert!(k <= n && mu > 0.0);
    ((n - k + 1)..=n).map(|l| 1.0 / (l as f64 * mu).powi(2)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn h0_is_zero() {
        assert_eq!(harmonic(0), 0.0);
    }

    #[test]
    fn small_values_exact() {
        assert!((harmonic(1) - 1.0).abs() < 1e-15);
        assert!((harmonic(2) - 1.5).abs() < 1e-15);
        assert!((harmonic(3) - (1.0 + 0.5 + 1.0 / 3.0)).abs() < 1e-15);
        assert!((harmonic(4) - (25.0 / 12.0)).abs() < 1e-14);
    }

    #[test]
    fn asymptotic_branch_is_continuous() {
        // Compare the two branches right at the crossover.
        let exact: f64 = (1..=10_001usize).rev().map(|l| 1.0 / l as f64).sum();
        let approx = harmonic(10_001);
        assert!((exact - approx).abs() < 1e-12, "{exact} vs {approx}");
    }

    #[test]
    fn monotone_increasing() {
        let mut prev = 0.0;
        for n in 1..100 {
            let h = harmonic(n);
            assert!(h > prev);
            prev = h;
        }
    }

    #[test]
    fn order_stat_max_of_n_is_hn_over_mu() {
        // k = n: expected maximum = H_n / mu.
        let v = expected_kth_of_n_exponential(5, 5, 2.0);
        assert!((v - harmonic(5) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn order_stat_min_of_n() {
        // k = 1: expected minimum of n Exp(mu) = 1/(n mu).
        let v = expected_kth_of_n_exponential(1, 10, 1.0);
        assert!((v - 0.1).abs() < 1e-12);
    }

    #[test]
    fn order_stat_matches_monte_carlo() {
        let (n, k, mu) = (10, 7, 3.0);
        let expect = expected_kth_of_n_exponential(k, n, mu);
        let mut r = Rng::new(77);
        let trials = 100_000;
        let mut acc = 0.0;
        let mut buf = vec![0.0f64; n];
        for _ in 0..trials {
            for b in buf.iter_mut() {
                *b = r.exponential(mu);
            }
            buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
            acc += buf[k - 1];
        }
        let mc = acc / trials as f64;
        assert!((mc - expect).abs() < 5e-3, "mc={mc} expect={expect}");
    }

    #[test]
    fn variance_matches_monte_carlo() {
        let (n, k, mu) = (8, 5, 1.0);
        let expect = variance_kth_of_n_exponential(k, n, mu);
        let mean = expected_kth_of_n_exponential(k, n, mu);
        let mut r = Rng::new(78);
        let trials = 200_000;
        let mut acc = 0.0;
        let mut buf = vec![0.0f64; n];
        for _ in 0..trials {
            for b in buf.iter_mut() {
                *b = r.exponential(mu);
            }
            buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
            acc += (buf[k - 1] - mean).powi(2);
        }
        let mc = acc / trials as f64;
        assert!((mc - expect).abs() < 5e-3, "mc={mc} expect={expect}");
    }
}
