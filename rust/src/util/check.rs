//! Property-testing mini-framework (offline substitute for `proptest`).
//!
//! Runs a property over many pseudo-random cases with a deterministic
//! seed; on failure it reports the case index and seed so the exact
//! failing input can be reproduced, and performs greedy shrinking for
//! integer-vector inputs.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use hiercode::util::check::{check, Gen};
//! check("reverse twice is identity", 200, |g: &mut Gen| {
//!     let xs = g.vec_usize(0..50, 0..100);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::Range;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Trace of drawn values, for failure reports.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            trace: Vec::new(),
        }
    }

    /// Underlying RNG (for distributions not wrapped here).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize uniform in `range`.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        assert!(range.start < range.end, "empty range");
        let v = range.start + self.rng.next_below(range.end - range.start);
        self.trace.push(format!("usize:{v}"));
        v
    }

    /// f64 uniform in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.trace.push(format!("f64:{v:.6}"));
        v
    }

    /// bool with probability `p` of `true`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        let v = self.rng.next_f64() < p;
        self.trace.push(format!("bool:{v}"));
        v
    }

    /// Vector of usizes: length drawn from `len`, elements from `elem`.
    pub fn vec_usize(&mut self, len: Range<usize>, elem: Range<usize>) -> Vec<usize> {
        let n = if len.start == len.end {
            len.start
        } else {
            self.usize_in(len)
        };
        (0..n).map(|_| self.usize_in(elem.clone())).collect()
    }

    /// Vector of f64s in `[lo, hi)` of length `n`.
    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// A valid `(n, k)` MDS parameter pair with `1 <= k <= n <= max_n`.
    pub fn code_params(&mut self, max_n: usize) -> (usize, usize) {
        let n = self.usize_in(1..max_n + 1);
        let k = self.usize_in(1..n + 1);
        (n, k)
    }

    /// A uniformly random `k`-subset of `[0, n)`.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        let s = self.rng.subset(n, k);
        self.trace.push(format!("subset:{s:?}"));
        s
    }
}

/// Run `prop` over `cases` pseudo-random cases. Panics (with seed and
/// case number) on the first failing case. Seed can be pinned via
/// `HIERCODE_CHECK_SEED` for reproduction.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let base_seed = std::env::var("HIERCODE_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with HIERCODE_CHECK_SEED={base_seed}): {msg}"
            );
        }
    }
}

/// Assert two f64 slices are element-wise close.
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol,
            "allclose failed at index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("add commutes", 100, |g| {
            let a = g.usize_in(0..1000);
            let b = g.usize_in(0..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 10, |g| {
            let x = g.usize_in(0..10);
            assert!(x > 100, "x was {x}");
        });
    }

    #[test]
    fn code_params_valid() {
        check("code params ordered", 500, |g| {
            let (n, k) = g.code_params(64);
            assert!(k >= 1 && k <= n && n <= 64);
        });
    }

    #[test]
    fn allclose_passes_close() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, 1e-9);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_far() {
        assert_allclose(&[1.0], &[1.1], 1e-9, 1e-9);
    }
}
