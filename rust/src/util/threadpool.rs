//! A small fixed-size thread pool with scoped parallel-map.
//!
//! Used for the paper's **parallel decoding** (§IV: the `n2` intra-group
//! codes decode in parallel) and for parallelizing Monte-Carlo trials.
//! Offline substitute for `rayon`.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// Fixed pool of worker threads consuming a shared job queue.
///
/// The sender is wrapped in a `Mutex` so the pool is `Sync` and can be
/// shared behind an `Arc` (e.g. inside `HierarchicalCode`) across the
/// coordinator's threads.
pub struct ThreadPool {
    tx: Mutex<mpsc::Sender<Message>>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` threads (at least 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Message>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("hiercode-pool-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().expect("pool queue poisoned");
                            guard.recv()
                        };
                        match msg {
                            Ok(Message::Run(job)) => job(),
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("failed to spawn pool thread"),
            );
        }
        Self {
            tx: Mutex::new(tx),
            handles,
            size,
        }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(n)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .lock()
            .expect("pool sender poisoned")
            .send(Message::Run(Box::new(f)))
            .expect("pool receiver dropped");
    }

    /// Apply `f` to each item, in pool threads, preserving order of
    /// results. Blocks until all items are done. This is the shape the
    /// hierarchical decoder needs: `n2` independent intra-group decodes.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = f(item);
                // Receiver may have been dropped on panic elsewhere.
                let _ = rtx.send((i, out));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("pool worker panicked");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("missing result")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            for _ in 0..self.handles.len() {
                let _ = tx.send(Message::Shutdown);
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty_input() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn drop_joins_threads() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }
}
