//! Minimal leveled logger (offline substitute for `log` + `env_logger`).
//!
//! Controlled by `HIERCODE_LOG` (`error|warn|info|debug|trace`, default
//! `info`). The coordinator threads log through this; output goes to
//! stderr so figure/CSV output on stdout stays machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, ordered.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but recoverable.
    Warn = 1,
    /// Lifecycle events (default).
    Info = 2,
    /// Per-job details.
    Debug = 3,
    /// Per-message details.
    Trace = 4,
}

impl Level {
    fn from_env(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static INIT: OnceLock<()> = OnceLock::new();

fn max_level() -> u8 {
    INIT.get_or_init(|| {
        let lvl = std::env::var("HIERCODE_LOG")
            .map(|s| Level::from_env(&s))
            .unwrap_or(Level::Info);
        MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
    MAX_LEVEL.load(Ordering::Relaxed)
}

/// Override the level programmatically (tests, `--verbose`).
pub fn set_level(level: Level) {
    INIT.get_or_init(|| ());
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True if `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

/// Emit a log record. Prefer the [`crate::log_info!`]-style macros.
pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    eprintln!("[{now:14.3} {} {target}] {msg}", level.tag());
}

/// Log at `Error` level.
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

/// Log at `Warn` level.
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

/// Log at `Info` level.
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

/// Log at `Debug` level.
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

/// Log at `Trace` level.
#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_env("error"), Level::Error);
        assert_eq!(Level::from_env("WARN"), Level::Warn);
        assert_eq!(Level::from_env("bogus"), Level::Info);
        assert_eq!(Level::from_env("trace"), Level::Trace);
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }
}
