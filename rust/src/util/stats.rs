//! Summary statistics for Monte-Carlo estimates and benchmarks.

/// Online mean/variance accumulator (Welford's algorithm) — numerically
/// stable for the long Monte-Carlo runs behind Fig. 6.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn std_err(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std_dev() / (self.n as f64).sqrt()
        }
    }

    /// Normal-approximation 95% confidence half-width of the mean.
    pub fn ci95_half_width(&self) -> f64 {
        1.96 * self.std_err()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (Chan's parallel update).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample by linear interpolation. `q` in `[0, 1]`.
/// Sorts a copy — use on bench-sized (not MC-sized) samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!((0.0..=1.0).contains(&q), "q={q} out of range");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Fixed-bin latency histogram for coordinator metrics: power-of-two
/// buckets from 1µs to ~1000s, lock-free-friendly (plain counters that
/// the metrics layer wraps in atomics).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i counts observations in [2^i, 2^{i+1}) microseconds
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// 32 power-of-two buckets covering 1µs .. ~4295s.
    pub fn new() -> Self {
        Self {
            buckets: vec![0; 32],
            count: 0,
            sum: 0.0,
        }
    }

    /// Record a latency in seconds.
    pub fn record(&mut self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0);
        let idx = if us < 1.0 {
            0
        } else {
            (us.log2().floor() as usize).min(self.buckets.len() - 1)
        };
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += seconds;
    }

    /// Number of recorded observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean of recorded observations (seconds). Like
    /// [`Histogram::quantile`], an empty histogram reports the `NaN`
    /// sentinel — never a fake "zero latency" mean.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    ///
    /// An **empty** histogram has no quantiles: returns the `NaN`
    /// sentinel, never an arbitrary bucket edge — a `0.0` here would
    /// read as a fake "zero latency" p99 in every serializer
    /// downstream (JSON emitters render the sentinel as `null`).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (1u64 << (i + 1)) as f64 * 1e-6;
            }
        }
        (1u64 << self.buckets.len()) as f64 * 1e-6
    }

    /// Merge counts from another histogram.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_basic_moments() {
        let mut w = Welford::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            w.push(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // Sample variance of that classic dataset is 32/7.
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_mean_and_quantile() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(0.001); // 1ms
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.001).abs() < 1e-9);
        let p99 = h.quantile(0.99);
        assert!(p99 >= 0.001 && p99 <= 0.003, "p99={p99}");
    }

    #[test]
    fn empty_histogram_quantile_is_nan_sentinel() {
        let mut h = Histogram::new();
        assert_eq!(h.count(), 0);
        for q in [0.5, 0.95, 0.99] {
            assert!(
                h.quantile(q).is_nan(),
                "empty histogram q={q} must be NaN, not a bucket edge"
            );
        }
        // The mean reports the same sentinel.
        assert!(h.mean().is_nan());
        // One observation and the statistics are defined again.
        h.record(0.002);
        assert!(h.quantile(0.99).is_finite());
        assert!(h.mean().is_finite());
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(0.5);
        b.record(1.5);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!((a.mean() - 1.0).abs() < 1e-12);
    }
}
