//! Offline substrates: PRNG, statistics, harmonic numbers, logging,
//! thread pool, micro-benchmark harness and a property-testing
//! mini-framework.
//!
//! The build environment is fully offline with no `rand`, `criterion`,
//! `proptest` or `rayon` available, so this module provides the small,
//! well-tested subset of each that the rest of the crate needs.

pub mod bench;
pub mod check;
pub mod harmonic;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;
