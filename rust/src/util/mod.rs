//! Offline substrates: PRNG, statistics, harmonic numbers, logging,
//! micro-benchmark harness and a property-testing mini-framework.
//!
//! The build environment is fully offline with no `rand`, `criterion`
//! or `proptest` available, so this module provides the small,
//! well-tested subset of each that the rest of the crate needs
//! (`rayon`'s role is filled by `crate::parallel::DecodePool`).

pub mod bench;
pub mod check;
pub mod harmonic;
pub mod logging;
pub mod manifest;
pub mod rng;
pub mod stats;
