//! Deterministic pseudo-random number generation.
//!
//! Implements SplitMix64 (seeding) and xoshiro256++ (bulk generation),
//! plus the distributions the paper's latency model needs: uniform,
//! exponential (worker runtimes and ToR-link delays are `Exp(mu)` in
//! §III), and shuffling / subset sampling for erasure patterns.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
/// Reference: Steele, Lea, Flood, "Fast splittable pseudorandom number
/// generators", OOPSLA 2014.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the crate-wide PRNG. Fast, 256-bit state, passes
/// BigCrush; more than adequate for Monte-Carlo latency estimation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministically seed from a single `u64` via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in s.iter_mut() {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid for xoshiro; SplitMix64 cannot emit
        // four consecutive zeros, but be defensive anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Seed from the system clock — for exploratory CLI runs only; all
    /// tests and benches pass explicit seeds.
    pub fn from_entropy() -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED);
        Self::new(nanos ^ 0xA02B_DBF7_BB3C_0A7A)
    }

    /// Next 64 uniformly distributed bits (xoshiro256++ core).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift rejection).
    pub fn next_below(&mut self, n: usize) -> usize {
        assert!(n > 0, "next_below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as u64 as usize;
            }
            // Rejection branch (rare): recompute threshold once.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as u64 as usize;
            }
        }
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponentially distributed sample with rate `mu`
    /// (mean `1/mu`) — the paper's worker-completion and ToR-link model.
    #[inline]
    pub fn exponential(&mut self, mu: f64) -> f64 {
        assert!(mu > 0.0, "exponential rate must be positive");
        // Inverse CDF; 1 - U avoids ln(0).
        -(1.0 - self.next_f64()).ln() / mu
    }

    /// Shifted exponential: `shift + Exp(mu)` — the common refinement of
    /// the straggler model (Lee et al., 2017).
    pub fn shifted_exponential(&mut self, shift: f64, mu: f64) -> f64 {
        shift + self.exponential(mu)
    }

    /// Standard normal via Box–Muller (used only by stats tests).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random `k`-subset of `[0, n)`, in random order.
    /// Used to sample which workers respond first in decoder tests.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "subset k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Split off an independently-seeded child generator (for giving
    /// each simulated worker / thread its own stream).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.next_below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(11);
        let mu = 10.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(mu)).sum::<f64>() / n as f64;
        assert!(
            (mean - 1.0 / mu).abs() < 3e-3,
            "mean {mean} vs expected {}",
            1.0 / mu
        );
    }

    #[test]
    fn exponential_is_nonnegative_and_finite() {
        let mut r = Rng::new(13);
        for _ in 0..100_000 {
            let x = r.exponential(1.0);
            assert!(x.is_finite() && x >= 0.0);
        }
    }

    #[test]
    fn subset_is_valid() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let s = r.subset(20, 8);
            assert_eq!(s.len(), 8);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8, "no duplicates");
            assert!(sorted.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(21);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(1234);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
