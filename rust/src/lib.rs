//! # hiercode — Hierarchical Coding for Distributed Computing
//!
//! A production-grade reproduction of *"Hierarchical Coding for
//! Distributed Computing"* (Park, Lee, Sohn, Suh, Moon — KAIST, 2018).
//!
//! The crate provides:
//!
//! * [`coding`] — real-field systematic MDS erasure codes, the paper's
//!   two-level **hierarchical code**, and the baselines it is compared
//!   against (replication, product codes, polynomial codes) — all
//!   decoded through streaming [`coding::Decoder`] **sessions** that
//!   start elimination work at the `k`-th arrival (batch decode is a
//!   replay of the same sessions).
//! * [`linalg`] — the dense linear-algebra substrate (packed-microkernel
//!   GEMM, unrolled GEMV, partial-pivot LU with a blocked multi-RHS
//!   solve) every decoder is built on.
//! * [`parallel`] — the scoped decode work-pool (`DecodePool`) that
//!   fans group eliminations, multi-RHS solve panels and Monte-Carlo
//!   shards across `config.runtime.decode_threads` threads with
//!   bit-deterministic results (GEMM offers the same fan-out via
//!   `linalg::ops::matmul_with` for pool-bearing callers).
//! * [`scenario`] — the scenario layer: [`scenario::Topology`] /
//!   [`scenario::GroupSpec`] describe heterogeneous per-group worker
//!   counts, recovery thresholds and straggler profiles; config,
//!   coding, coordinator and sim all consume the same value.
//! * [`sim`] — a discrete-event simulator of the hierarchical cluster,
//!   the auxiliary Markov chain of Lemma 1 (lower bound), the Lemma 2 /
//!   Theorem 2 upper bounds, Monte-Carlo latency estimation, and the
//!   load-allocation optimizer (`sim::allocate`).
//! * [`coordinator`] — the runnable system: threaded master / submaster
//!   / worker topology with batching, routing, straggler handling and
//!   two-level parallel decoding on the request path.
//! * [`controlplane`] — the control plane: compiled scenario artifacts
//!   (versioned, checksummed `.hca` binaries), generation-stamped
//!   zero-drop hot reload of the serving config, and the framed admin
//!   protocol behind `hiercode compile` / `hiercode admin`.
//! * [`sync`] — the synchronization facade the coordinator builds on:
//!   poison-transparent locks, the admission gate and drain state
//!   machine, and (under `--features modelcheck`) an in-repo
//!   loom-style exhaustive interleaving explorer.
//! * [`transport`] — the links between master ↔ submasters ↔ workers:
//!   the in-memory FIFO fast path and a socket transport (UDS/TCP)
//!   with a versioned, checksummed wire format, so submaster/worker
//!   trees run as separate OS processes (`hiercode node`).
//! * [`runtime`] — the PJRT bridge that loads AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust.
//! * [`config`], [`cli`], [`util`] — config system (own JSON parser),
//!   launcher, and offline substitutes for rand/criterion/proptest.
//! * [`figures`] — regenerates every table and figure of the paper.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod cli;
pub mod coding;
pub mod config;
pub mod controlplane;
pub mod coordinator;
pub mod figures;
pub mod linalg;
pub mod parallel;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod sync;
pub mod transport;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Invalid code / cluster / simulation parameters.
    InvalidParams(String),
    /// Numerical failure (singular system, non-finite values).
    Numerical(String),
    /// Not enough shards / groups arrived to decode.
    Insufficient { needed: usize, got: usize },
    /// Config file / JSON problems.
    Config(String),
    /// Artifact loading / PJRT execution problems.
    Runtime(String),
    /// Coordinator protocol violation or channel failure.
    Coordinator(String),
    /// Admission control: the model's submission queue is full —
    /// explicit backpressure, retry later.
    Busy {
        /// The model whose queue was full.
        model: String,
    },
    /// The request's deadline expired before it was served.
    DeadlineExceeded,
    /// A control-plane rollout was rejected because the candidate
    /// artifact is incompatible with the running cluster (changed
    /// scheme, group structure, or transport) — nothing was applied.
    Incompatible(String),
    /// I/O errors.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidParams(m) => write!(f, "invalid parameters: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Insufficient { needed, got } => {
                write!(f, "insufficient shards: needed {needed}, got {got}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Busy { model } => {
                write!(f, "busy: model '{model}' queue is full (backpressure; retry later)")
            }
            Error::DeadlineExceeded => {
                write!(f, "deadline exceeded before the request was served")
            }
            Error::Incompatible(m) => {
                write!(f, "incompatible rollout (nothing applied): {m}")
            }
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}
