//! The scenario layer: one heterogeneous-topology description shared by
//! every layer of the system.
//!
//! A [`Topology`] is the full description of a two-tier deployment as a
//! *scenario*: per group (rack) a [`GroupSpec`] carrying the inner code
//! parameters `(n1_g, k1_g)`, that group's straggler profile (worker
//! completion model, uplink model, optional wall-clock scale override)
//! and its dead-worker set, plus the outer recovery threshold `k2`.
//!
//! The same `Topology` value flows through four layers:
//!
//! * `config` parses a `groups: [...]` array (or expands the uniform
//!   `(n1,k1,n2,k2)` sugar) into one;
//! * `coding` builds per-group generator matrices and decoder sessions
//!   sized by `k1_g` from it ([`crate::coding::CodedScheme::topology`]
//!   returns it);
//! * `coordinator` spawns `n1_g` workers per group with that group's
//!   straggler profile and thresholds each submaster at `k1_g`;
//! * `sim` computes `E[T]` bounds and Monte-Carlo estimates over it
//!   (`sim::montecarlo::expected_latency_topology`,
//!   `sim::bounds::topology_upper`) and `sim::allocate` searches the
//!   `k1_g` assignment minimizing the upper bound.
//!
//! One scenario type, four layers — the simulated cluster and the live
//! cluster cannot drift apart.

use crate::coding::hierarchical::HierarchicalParams;
use crate::sim::straggler::StragglerModel;
use crate::sim::SimParams;
use crate::{Error, Result};

/// The paper's default worker completion rate `µ1`.
pub const DEFAULT_MU1: f64 = 10.0;
/// The paper's default group→master (ToR) link rate `µ2`.
pub const DEFAULT_MU2: f64 = 1.0;
/// Ceiling on per-worker sub-task counts: the group decode is a
/// `(k1·r)×(k1·r)` elimination, so an absurd `r` silently turns the
/// decode hot path quadratic-in-`r` — reject it at validation instead.
pub const MAX_SUBTASKS: usize = 64;

/// One group (rack) of a [`Topology`]: inner code parameters plus the
/// group's straggler profile.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupSpec {
    /// Workers in this group (`n1_g`).
    pub n1: usize,
    /// Inner recovery threshold (`k1_g`): how many of the group's
    /// workers must respond before the group decodes.
    pub k1: usize,
    /// Worker completion-time model (the paper's `Exp(µ1)`).
    pub worker: StragglerModel,
    /// Group→master link-delay model (the paper's `Exp(µ2)`).
    pub link: StragglerModel,
    /// Optional relative slowdown multiplier on this group's worker
    /// and link delays (`None` = 1). Honored by **both** the live
    /// cluster (its wall-clock scale is the global scale times this)
    /// and every sim/analysis path (samples and exponential rates are
    /// scaled accordingly) — per-group speed is model, not rendering.
    pub scale: Option<f64>,
    /// In-group worker indices that never produce results (failure
    /// domains baked into the scenario, merged with any ad-hoc
    /// `FaultConfig` at launch).
    pub dead_workers: Vec<usize>,
    /// Partial-work mode (Ferdinand–Draper, arXiv:1806.10250): each
    /// worker's shard is encoded as `r` sequentially-computed coded
    /// sub-tasks, streamed one result per completed sub-task, and the
    /// group recovers from **any** `k1·r` sub-results — fast workers,
    /// stragglers' partial work, or both. `1` (the default) is the
    /// paper's all-or-nothing task model, bit-identical to pre-partial
    /// behavior on every layer.
    pub subtasks: usize,
}

impl GroupSpec {
    /// A group with the paper's default straggler profile.
    pub fn new(n1: usize, k1: usize) -> Self {
        Self {
            n1,
            k1,
            worker: StragglerModel::exp(DEFAULT_MU1),
            link: StragglerModel::exp(DEFAULT_MU2),
            scale: None,
            dead_workers: Vec::new(),
            subtasks: 1,
        }
    }

    /// Workers of this group that can actually respond.
    pub fn alive(&self) -> usize {
        let dead = (0..self.n1)
            .filter(|j| self.dead_workers.contains(j))
            .count();
        self.n1 - dead
    }

    /// Whether this group can ever reach its recovery threshold.
    pub fn can_complete(&self) -> bool {
        self.alive() >= self.k1
    }

    /// The group's delay multiplier (`scale`, defaulting to 1).
    pub fn slowdown(&self) -> f64 {
        self.scale.unwrap_or(1.0)
    }

    /// Sub-results this group must collect before it can decode:
    /// `k1 · subtasks` (reduces to `k1` in the all-or-nothing model).
    pub fn recovery_subresults(&self) -> usize {
        self.k1 * self.subtasks
    }

    /// Exponential rates `(µ1, µ2)` when both models are the paper's
    /// exponentials (the analytic §III machinery needs them).
    pub fn exponential_rates(&self) -> Option<(f64, f64)> {
        match (self.worker, self.link) {
            (
                StragglerModel::Exponential { mu: mu1 },
                StragglerModel::Exponential { mu: mu2 },
            ) => Some((mu1, mu2)),
            _ => None,
        }
    }
}

/// A full two-tier scenario: the per-group specs plus the outer
/// recovery threshold `k2`.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// Per-group specs, in flat worker-index order.
    pub groups: Vec<GroupSpec>,
    /// Outer recovery threshold: how many groups must deliver.
    pub k2: usize,
}

impl Topology {
    /// Uniform `(n1,k1)×(n2,k2)` topology with the paper's default
    /// straggler profile — what the config sugar expands to.
    pub fn homogeneous(n1: usize, k1: usize, n2: usize, k2: usize) -> Self {
        Self {
            groups: (0..n2).map(|_| GroupSpec::new(n1, k1)).collect(),
            k2,
        }
    }

    /// Uniform code parameters with explicit straggler models on every
    /// group (the event engine's wrapper path).
    pub fn homogeneous_with_models(
        n1: usize,
        k1: usize,
        n2: usize,
        k2: usize,
        worker: StragglerModel,
        link: StragglerModel,
    ) -> Self {
        Self {
            groups: (0..n2)
                .map(|_| GroupSpec {
                    worker,
                    link,
                    ..GroupSpec::new(n1, k1)
                })
                .collect(),
            k2,
        }
    }

    /// The relay topology of a flat scheme: one group holding all `n`
    /// workers with recovery threshold `k`.
    pub fn single_group(n: usize, k: usize) -> Self {
        Self {
            groups: vec![GroupSpec::new(n, k)],
            k2: 1,
        }
    }

    /// Number of groups (`n2`).
    pub fn n2(&self) -> usize {
        self.groups.len()
    }

    /// Total workers `Σ_g n1_g`.
    pub fn total_workers(&self) -> usize {
        self.groups.iter().map(|g| g.n1).sum()
    }

    /// Per-group worker counts in flat-index order.
    pub fn group_sizes(&self) -> Vec<usize> {
        self.groups.iter().map(|g| g.n1).collect()
    }

    /// Structural validation: outer threshold in range, per-group
    /// `1 <= k1_g <= n1_g`, dead-worker indices in range.
    pub fn validate(&self) -> Result<()> {
        if self.groups.is_empty() || self.k2 == 0 || self.k2 > self.groups.len() {
            return Err(Error::InvalidParams(format!(
                "topology: need 1 <= k2 <= n2, got ({}, {})",
                self.groups.len(),
                self.k2
            )));
        }
        for (g, spec) in self.groups.iter().enumerate() {
            if spec.k1 == 0 || spec.k1 > spec.n1 {
                return Err(Error::InvalidParams(format!(
                    "topology group {g}: need 1 <= k1 <= n1, got ({}, {})",
                    spec.n1, spec.k1
                )));
            }
            if let Some(&j) = spec.dead_workers.iter().find(|&&j| j >= spec.n1) {
                return Err(Error::InvalidParams(format!(
                    "topology group {g}: dead worker {j} out of n1={}",
                    spec.n1
                )));
            }
            if let Some(s) = spec.scale {
                if !s.is_finite() || s <= 0.0 {
                    return Err(Error::InvalidParams(format!(
                        "topology group {g}: scale must be a positive finite \
                         multiplier, got {s}"
                    )));
                }
            }
            if spec.subtasks == 0 || spec.subtasks > MAX_SUBTASKS {
                return Err(Error::InvalidParams(format!(
                    "topology group {g}: subtasks must be in 1..={MAX_SUBTASKS}, \
                     got {}",
                    spec.subtasks
                )));
            }
        }
        Ok(())
    }

    /// Whether enough groups can complete for a job to ever decode.
    pub fn survivable(&self) -> bool {
        self.groups.iter().filter(|g| g.can_complete()).count() >= self.k2
    }

    /// True when every group has the same `(n1, k1)` — the homogeneous
    /// code of the paper's evaluation.
    pub fn is_uniform_code(&self) -> bool {
        self.groups
            .windows(2)
            .all(|w| w[0].n1 == w[1].n1 && w[0].k1 == w[1].k1)
    }

    /// The coding-layer view: per-group `(n1_g, k1_g)` plus `(n2, k2)`.
    pub fn hierarchical_params(&self) -> HierarchicalParams {
        HierarchicalParams {
            n1: self.groups.iter().map(|g| g.n1).collect(),
            k1: self.groups.iter().map(|g| g.k1).collect(),
            n2: self.groups.len(),
            k2: self.k2,
        }
    }

    /// The paper's homogeneous-exponential parameters, when this
    /// topology is exactly that scenario: uniform code, every group on
    /// the same `Exp(µ1)`/`Exp(µ2)` profile, no dead workers. The
    /// Monte-Carlo driver uses this to route uniform topologies through
    /// the seed's Rényi-spacings sampler bit-identically.
    pub fn sim_params(&self) -> Option<SimParams> {
        if !self.is_uniform_code() {
            return None;
        }
        let first = self.groups.first()?;
        let (mu1, mu2) = first.exponential_rates()?;
        for g in &self.groups {
            if !g.dead_workers.is_empty()
                || g.slowdown() != 1.0
                || g.subtasks != 1
                || g.exponential_rates() != Some((mu1, mu2))
            {
                return None;
            }
        }
        Some(SimParams {
            n1: first.n1,
            k1: first.k1,
            n2: self.groups.len(),
            k2: self.k2,
            mu1,
            mu2,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_expansion_is_uniform() {
        let t = Topology::homogeneous(4, 2, 3, 2);
        assert_eq!(t.n2(), 3);
        assert_eq!(t.total_workers(), 12);
        assert!(t.is_uniform_code());
        assert!(t.validate().is_ok());
        assert!(t.survivable());
        let p = t.sim_params().expect("uniform default profile");
        assert_eq!((p.n1, p.k1, p.n2, p.k2), (4, 2, 3, 2));
        assert_eq!((p.mu1, p.mu2), (DEFAULT_MU1, DEFAULT_MU2));
        let hp = t.hierarchical_params();
        assert_eq!(hp, HierarchicalParams::homogeneous(4, 2, 3, 2));
    }

    #[test]
    fn heterogeneous_is_not_uniform_and_has_no_sim_params() {
        let t = Topology {
            groups: vec![GroupSpec::new(4, 2), GroupSpec::new(6, 3)],
            k2: 1,
        };
        assert!(!t.is_uniform_code());
        assert!(t.sim_params().is_none());
        assert!(t.validate().is_ok());
        assert_eq!(t.group_sizes(), vec![4, 6]);
    }

    #[test]
    fn dead_workers_and_survivability() {
        let mut t = Topology::homogeneous(3, 2, 3, 2);
        t.groups[0].dead_workers = vec![0, 1]; // alive 1 < k1 2
        assert!(t.validate().is_ok());
        assert!(!t.groups[0].can_complete());
        assert!(t.survivable(), "2 healthy groups >= k2 = 2");
        t.groups[1].dead_workers = vec![2, 0];
        assert!(!t.survivable());
        // Dead workers break the uniform-exponential fast path.
        assert!(t.sim_params().is_none());
        // Out-of-range dead index rejected.
        t.groups[2].dead_workers = vec![7];
        assert!(t.validate().is_err());
    }

    #[test]
    fn structural_validation() {
        assert!(Topology { groups: vec![], k2: 1 }.validate().is_err());
        assert!(Topology::homogeneous(3, 2, 3, 4).validate().is_err()); // k2 > n2
        assert!(Topology::homogeneous(2, 3, 3, 2).validate().is_err()); // k1 > n1
        let mut t = Topology::homogeneous(3, 2, 3, 2);
        t.groups[1].scale = Some(-1.0);
        assert!(t.validate().is_err());
        t.groups[1].scale = Some(0.0);
        assert!(t.validate().is_err(), "zero multiplier rejected");
    }

    #[test]
    fn slowdown_multiplier_blocks_uniform_fast_path() {
        let mut t = Topology::homogeneous(4, 2, 2, 1);
        assert!(t.sim_params().is_some());
        t.groups[1].scale = Some(2.0);
        assert_eq!(t.groups[1].slowdown(), 2.0);
        assert!(t.validate().is_ok());
        assert!(t.sim_params().is_none(), "scaled group is not the paper model");
    }

    #[test]
    fn single_group_relay_shape() {
        let t = Topology::single_group(9, 4);
        assert_eq!(t.n2(), 1);
        assert_eq!(t.k2, 1);
        assert_eq!(t.total_workers(), 9);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn subtasks_validated_and_block_uniform_fast_path() {
        let mut t = Topology::homogeneous(4, 2, 2, 1);
        assert_eq!(t.groups[0].subtasks, 1, "all-or-nothing by default");
        assert_eq!(t.groups[0].recovery_subresults(), 2);
        assert!(t.sim_params().is_some());
        t.groups[1].subtasks = 4;
        assert!(t.validate().is_ok());
        assert_eq!(t.groups[1].recovery_subresults(), 8);
        assert!(
            t.sim_params().is_none(),
            "multi-round groups are not the paper's homogeneous model"
        );
        t.groups[1].subtasks = 0;
        assert!(t.validate().is_err(), "zero sub-tasks rejected");
        t.groups[1].subtasks = MAX_SUBTASKS + 1;
        assert!(t.validate().is_err(), "absurd sub-task count rejected");
    }

    #[test]
    fn per_group_rate_mismatch_blocks_fast_path() {
        let mut t = Topology::homogeneous(4, 2, 2, 1);
        t.groups[1].worker = StragglerModel::exp(3.0);
        assert!(t.sim_params().is_none());
        assert_eq!(t.groups[1].exponential_rates(), Some((3.0, DEFAULT_MU2)));
    }
}
