//! Fig. 6: expected total computation time and its bounds vs `k2`.
//!
//! Paper parameters: `n1 = (1+δ1)k1` with `δ1 = 1`, `n2 = 10`,
//! `µ1 = 10`, `µ2 = 1`; `k1 = 5` (Fig. 6a) or `k1 = 300` (Fig. 6b);
//! `k2` sweeps `1..=10`. Series: Monte-Carlo `E[T]`, the Markov-chain
//! lower bound `L` (Thm. 1 / Lemma 1), and the two upper bounds
//! (Lemma 2, Thm. 2).

use crate::sim::{bounds, markov, montecarlo, SimParams};
use crate::Result;

/// One `k2` point of the figure.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    /// Outer code dimension.
    pub k2: usize,
    /// Monte-Carlo `E[T]` with 95% CI half-width.
    pub expected: f64,
    /// CI half-width of `expected`.
    pub ci95: f64,
    /// Lower bound `L` (exact, via the Lemma 1 chain).
    pub lower: f64,
    /// Lemma 2 upper bound.
    pub upper_lemma2: f64,
    /// Theorem 2 upper bound.
    pub upper_thm2: f64,
}

/// Generate the figure's rows for a given `k1` (5 → Fig. 6a,
/// 300 → Fig. 6b).
pub fn generate(k1: usize, trials: usize, seed: u64) -> Result<Vec<Fig6Row>> {
    let mut rows = Vec::new();
    for k2 in 1..=10 {
        let p = SimParams::fig6(k1, k2);
        let est = montecarlo::expected_latency(&p, trials, seed + k2 as u64)?;
        rows.push(Fig6Row {
            k2,
            expected: est.mean,
            ci95: est.ci95,
            lower: markov::lower_bound(&p)?,
            upper_lemma2: bounds::lemma2_upper(&p)?,
            upper_thm2: bounds::theorem2_upper(&p)?,
        });
    }
    Ok(rows)
}

/// Render rows as CSV.
pub fn to_csv(rows: &[Fig6Row]) -> String {
    let mut out = String::from("k2,E[T],ci95,lower_L,upper_lemma2,upper_thm2\n");
    for r in rows {
        out.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6},{:.6}\n",
            r.k2, r.expected, r.ci95, r.lower, r.upper_lemma2, r.upper_thm2
        ));
    }
    out
}

/// Print the figure (CSV + a quick sanity summary on stderr).
pub fn run(k1: usize, trials: usize, seed: u64) -> Result<Vec<Fig6Row>> {
    let rows = generate(k1, trials, seed)?;
    println!("# Fig 6{} — k1={k1}, n1={}, n2=10, mu1=10, mu2=1, trials={trials}",
        if k1 <= 50 { "a" } else { "b" }, 2 * k1);
    print!("{}", to_csv(&rows));
    let violations = rows
        .iter()
        .filter(|r| r.lower > r.expected + 3.0 * r.ci95)
        .count();
    eprintln!("fig6(k1={k1}): {} rows, lower-bound violations: {violations}", rows.len());
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_shape() {
        // Small trial count for test speed; the structural claims hold
        // regardless of MC noise at these margins.
        let rows = generate(5, 4_000, 1).unwrap();
        assert_eq!(rows.len(), 10);
        for r in &rows {
            // Sandwich: L ≤ E[T] ≤ Lemma2 (Thm2 not valid at k1=5).
            assert!(
                r.lower <= r.expected + 3.0 * r.ci95,
                "k2={}: L={} E[T]={}",
                r.k2,
                r.lower,
                r.expected
            );
            assert!(
                r.expected <= r.upper_lemma2 + 3.0 * r.ci95,
                "k2={}: E[T]={} UB={}",
                r.k2,
                r.expected,
                r.upper_lemma2
            );
        }
        // Monotone in k2.
        for w in rows.windows(2) {
            assert!(w[1].expected >= w[0].expected - 3.0 * (w[0].ci95 + w[1].ci95));
        }
    }

    #[test]
    fn fig6b_thm2_tight_at_large_k1() {
        let rows = generate(300, 1_500, 2).unwrap();
        for r in &rows {
            assert!(r.expected <= r.upper_thm2 + 3.0 * r.ci95);
            // Paper: Thm 2 is the tighter bound at k1=300.
            assert!(
                r.upper_thm2 < r.upper_lemma2,
                "k2={}: thm2 {} should beat lemma2 {}",
                r.k2,
                r.upper_thm2,
                r.upper_lemma2
            );
        }
    }

    #[test]
    fn csv_renders() {
        let rows = generate(5, 500, 3).unwrap();
        let csv = to_csv(&rows);
        assert!(csv.lines().count() == 11);
        assert!(csv.starts_with("k2,"));
    }
}
