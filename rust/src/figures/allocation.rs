//! Allocation figure: uniform vs optimized `k1_g` assignments as
//! straggler skew grows.
//!
//! A fixed fleet (5 groups × 10 workers, `k2 = 3`, total inner
//! dimension 25) faces increasingly skewed per-group worker rates
//! (`µ1_g = skew^{2−g}`, centered on 1). For each skew level the sweep
//! reports the §III upper bound, the Monte-Carlo `E[T]`, and the §IV
//! decode-cost model for both the uniform assignment and the one
//! [`crate::sim::allocate::optimize`] finds — the gap is the payoff of
//! treating rate allocation as a first-class scenario knob.

use crate::parallel::DecodePool;
use crate::scenario::Topology;
use crate::sim::allocate::{self, AllocationProblem};
use crate::sim::{bounds, montecarlo};
use crate::Result;

/// One skew point of the figure.
#[derive(Clone, Debug)]
pub struct AllocRow {
    /// Rate skew factor between adjacent groups.
    pub skew: f64,
    /// §III upper bound, uniform assignment.
    pub uniform_bound: f64,
    /// §III upper bound, optimized assignment.
    pub opt_bound: f64,
    /// Monte-Carlo `E[T]`, uniform.
    pub uniform_expected: f64,
    /// CI half-width of `uniform_expected`.
    pub uniform_ci95: f64,
    /// Monte-Carlo `E[T]`, optimized.
    pub opt_expected: f64,
    /// CI half-width of `opt_expected`.
    pub opt_ci95: f64,
    /// §IV decode-cost model, uniform.
    pub uniform_decode_cost: f64,
    /// §IV decode-cost model, optimized.
    pub opt_decode_cost: f64,
    /// The optimized per-group thresholds.
    pub opt_k1: Vec<usize>,
}

/// §IV decode-cost model generalized to heterogeneous groups: the `k2`
/// lightest-mean groups decode in parallel (`max_g k1_g^β`), then the
/// outer decode pays `k2^β` per recovered sub-block (`Σ k1_g / k2`
/// effective blocks). Reduces to Table I's `k1^β + k1·k2^β` when
/// uniform.
pub fn decode_cost_model(topo: &Topology, beta: f64) -> f64 {
    let mut means: Vec<(f64, usize)> = (0..topo.n2())
        .filter_map(|g| bounds::topology_group_mean(topo, g).map(|m| (m, g)))
        .collect();
    if means.len() < topo.k2 {
        // Fewer usable groups than the outer threshold: the decode
        // never happens, so its cost is unbounded — mirror
        // `topology_upper`'s refusal instead of understating.
        return f64::INFINITY;
    }
    means.sort_by(|a, b| a.0.total_cmp(&b.0));
    let used: Vec<usize> = means.iter().take(topo.k2).map(|&(_, g)| g).collect();
    let k2 = topo.k2 as f64;
    let max_inner = used
        .iter()
        .map(|&g| (topo.groups[g].k1 as f64).powf(beta))
        .fold(0.0f64, f64::max);
    let mean_k1 = used.iter().map(|&g| topo.groups[g].k1 as f64).sum::<f64>() / k2;
    max_inner + mean_k1 * k2.powf(beta)
}

/// The figure's fixed fleet at a given skew.
fn problem(skew: f64) -> AllocationProblem {
    let n2 = 5usize;
    AllocationProblem {
        n1: vec![10; n2],
        k2: 3,
        mu1: (0..n2).map(|g| skew.powi(2 - g as i32)).collect(),
        mu2: vec![1.0; n2],
        total_k1: 25,
    }
}

/// Generate the sweep.
pub fn generate(trials: usize, seed: u64) -> Result<Vec<AllocRow>> {
    let pool = DecodePool::serial();
    let mut rows = Vec::new();
    for (i, &skew) in [1.0f64, 1.5, 2.0, 3.0, 4.0].iter().enumerate() {
        let p = problem(skew);
        let alloc = allocate::optimize(&p)?;
        let uni_topo = p.topology(&alloc.uniform_k1);
        let opt_topo = p.topology(&alloc.k1);
        let uni =
            montecarlo::expected_latency_topology(&uni_topo, trials, seed + i as u64, &pool)?;
        let opt =
            montecarlo::expected_latency_topology(&opt_topo, trials, seed + i as u64, &pool)?;
        rows.push(AllocRow {
            skew,
            uniform_bound: alloc.uniform_bound,
            opt_bound: alloc.bound,
            uniform_expected: uni.mean,
            uniform_ci95: uni.ci95,
            opt_expected: opt.mean,
            opt_ci95: opt.ci95,
            uniform_decode_cost: decode_cost_model(&uni_topo, 2.0),
            opt_decode_cost: decode_cost_model(&opt_topo, 2.0),
            opt_k1: alloc.k1,
        });
    }
    Ok(rows)
}

/// Render rows as CSV.
pub fn to_csv(rows: &[AllocRow]) -> String {
    let mut out = String::from(
        "skew,uniform_bound,opt_bound,uniform_E[T],uniform_ci95,opt_E[T],opt_ci95,\
         uniform_dec_cost,opt_dec_cost,opt_k1\n",
    );
    for r in rows {
        let k1s: Vec<String> = r.opt_k1.iter().map(|k| k.to_string()).collect();
        out.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.1},{:.1},{}\n",
            r.skew,
            r.uniform_bound,
            r.opt_bound,
            r.uniform_expected,
            r.uniform_ci95,
            r.opt_expected,
            r.opt_ci95,
            r.uniform_decode_cost,
            r.opt_decode_cost,
            k1s.join("|"),
        ));
    }
    out
}

/// Print the figure.
pub fn run(trials: usize, seed: u64) -> Result<Vec<AllocRow>> {
    println!(
        "# Allocation sweep — 5 groups x 10 workers, k2=3, total k1=25, \
         mu1_g = skew^(2-g), mu2=1, beta=2, trials={trials}"
    );
    let rows = generate(trials, seed)?;
    print!("{}", to_csv(&rows));
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimized_never_loses_and_wins_under_skew() {
        let rows = generate(8_000, 3).unwrap();
        assert_eq!(rows.len(), 5);
        for r in &rows {
            // The optimizer starts from uniform: it can never lose.
            assert!(
                r.opt_bound <= r.uniform_bound,
                "skew {}: opt bound {} > uniform {}",
                r.skew,
                r.opt_bound,
                r.uniform_bound
            );
            // Bounds dominate the simulation.
            assert!(
                r.uniform_expected <= r.uniform_bound + 3.0 * r.uniform_ci95,
                "skew {}: E[T] {} above its bound {}",
                r.skew,
                r.uniform_expected,
                r.uniform_bound
            );
            assert!(r.opt_expected <= r.opt_bound + 3.0 * r.opt_ci95);
            assert_eq!(r.opt_k1.iter().sum::<usize>(), 25);
        }
        // Heavy skew: the optimized assignment is strictly better in
        // bound and no worse in simulated E[T].
        let heavy = rows.last().unwrap();
        assert!(heavy.opt_bound < heavy.uniform_bound * 0.995);
        assert!(
            heavy.opt_expected
                <= heavy.uniform_expected + 3.0 * (heavy.opt_ci95 + heavy.uniform_ci95)
        );
    }

    #[test]
    fn csv_renders() {
        let rows = generate(2_000, 4).unwrap();
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), 6);
        assert!(csv.starts_with("skew,"));
        assert!(csv.contains('|'), "opt_k1 vector rendered");
    }

    #[test]
    fn decode_cost_model_reduces_to_table1_when_uniform() {
        use crate::scenario::Topology;
        let t = Topology::homogeneous(10, 4, 5, 3);
        let beta = 2.0;
        let expect = 4.0f64.powf(beta) + 4.0 * 3.0f64.powf(beta);
        assert!((decode_cost_model(&t, beta) - expect).abs() < 1e-9);
    }
}
