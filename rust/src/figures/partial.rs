//! E8 — partial-work tradeoff: `E[T]` and decode cost vs
//! `subtasks_per_worker` (`r`), reproducing the Ferdinand–Draper
//! multi-round result (arXiv:1806.10250) on a straggler-skewed
//! hierarchical topology.
//!
//! The scenario pins the slow rack onto the critical path (`k2 = n2`,
//! one group an order of magnitude slower), so every unit of straggler
//! partial work harvested shortens the job. As `r` grows, `E[T]` falls
//! toward the fluid limit `k1/(n1·µ1)` — but each group's decode is a
//! `(k1·r)×(k1·r)` elimination, so decode flops grow with `r`: the
//! tradeoff the `subtasks_per_worker` knob exposes.

use crate::coding::{compute_all_products, select_results, CodedScheme, HierarchicalCode};
use crate::linalg::Matrix;
use crate::parallel::DecodePool;
use crate::scenario::{GroupSpec, Topology};
use crate::sim::bounds;
use crate::sim::montecarlo::expected_latency_topology;
use crate::sim::straggler::StragglerModel;
use crate::util::rng::Rng;
use crate::Result;

/// One `r` point of the sweep.
#[derive(Clone, Debug)]
pub struct PartialRow {
    /// Sub-tasks per worker.
    pub r: usize,
    /// Monte-Carlo `E[T]` of the multi-round model.
    pub expected: f64,
    /// CI half-width of `expected`.
    pub ci95: f64,
    /// Spacing-domination upper bound ([`bounds::topology_upper`]).
    pub upper: f64,
    /// Measured decode flops of one job at this `r` (parity-heavy
    /// arrivals, through the real streaming sessions).
    pub decode_flops: u64,
}

/// The sweep's straggler-skewed scenario at a given `r`: two healthy
/// racks and one 20× slower rack, all required (`k2 = n2 = 3`).
pub fn scenario(r: usize) -> Topology {
    let mk = |mu1: f64| GroupSpec {
        worker: StragglerModel::exp(mu1),
        link: StragglerModel::exp(1.0),
        subtasks: r,
        ..GroupSpec::new(10, 5)
    };
    Topology {
        groups: vec![mk(10.0), mk(10.0), mk(0.5)],
        k2: 3,
    }
}

/// The `r` values the figure sweeps.
pub const R_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Generate the sweep's rows.
pub fn generate(trials: usize, seed: u64) -> Result<Vec<PartialRow>> {
    let pool = DecodePool::serial();
    // One fixed matrix shape valid for every r in the sweep:
    // k2·k1·r = 15r divides 120 for r ∈ {1, 2, 4, 8}.
    let (rows, cols) = (120usize, 8usize);
    let mut rng = Rng::new(seed ^ 0xE8);
    let a = Matrix::from_fn(rows, cols, |_, _| rng.uniform(-1.0, 1.0));
    let x = Matrix::from_fn(cols, 1, |_, _| rng.uniform(-1.0, 1.0));
    let mut out = Vec::new();
    for (i, &r) in R_SWEEP.iter().enumerate() {
        let topo = scenario(r);
        let est = expected_latency_topology(&topo, trials, seed + i as u64, &pool)?;
        let upper = bounds::topology_upper(&topo)?;
        // Measured decode cost of one job: parity-heavy arrivals (the
        // last k1 workers of every group) through the same streaming
        // sessions the live cluster runs.
        let code = HierarchicalCode::from_topology(topo)?;
        let shards = code.encode(&a)?;
        let all = compute_all_products(&shards, &x);
        let picks: Vec<usize> = (0..3).flat_map(|g| (5..10).map(move |j| g * 10 + j)).collect();
        let decoded = code.decode(&select_results(&all, &picks), rows)?;
        out.push(PartialRow {
            r,
            expected: est.mean,
            ci95: est.ci95,
            upper,
            decode_flops: decoded.flops,
        });
    }
    Ok(out)
}

/// Render rows as CSV.
pub fn to_csv(rows: &[PartialRow]) -> String {
    let mut out = String::from("r,E[T],ci95,upper_bound,decode_flops\n");
    for r in rows {
        out.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{}\n",
            r.r, r.expected, r.ci95, r.upper, r.decode_flops
        ));
    }
    out
}

/// Print the figure (CSV + a quick sanity summary on stderr).
pub fn run(trials: usize, seed: u64) -> Result<Vec<PartialRow>> {
    let rows = generate(trials, seed)?;
    println!(
        "# E8 partial-work sweep — (10,5)x(3,3), mu1=[10,10,0.5], mu2=1, \
         trials={trials}"
    );
    print!("{}", to_csv(&rows));
    let base = rows[0].expected;
    for r in &rows[1..] {
        eprintln!(
            "partial: r={} E[T] {:.4} vs r=1 {:.4} ({:+.1}%), decode flops {}",
            r.r,
            r.expected,
            base,
            (r.expected / base - 1.0) * 100.0,
            r.decode_flops
        );
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_round_lowers_latency_and_raises_decode_cost() {
        let rows = generate(20_000, 7).unwrap();
        assert_eq!(rows.len(), R_SWEEP.len());
        let r1 = &rows[0];
        assert_eq!(r1.r, 1);
        for row in &rows[1..] {
            // Acceptance: E[T] strictly below the r = 1 baseline on the
            // straggler-skewed topology.
            assert!(
                row.expected + 3.0 * (row.ci95 + r1.ci95) < r1.expected,
                "r={}: E[T] {} must sit strictly below r=1's {}",
                row.r,
                row.expected,
                r1.expected
            );
            // The §III bound still dominates the multi-round model.
            assert!(
                row.expected <= row.upper + 3.0 * row.ci95,
                "r={}: E[T] {} exceeds bound {}",
                row.r,
                row.expected,
                row.upper
            );
            // The price: a (k1·r)² elimination per group.
            assert!(
                row.decode_flops > r1.decode_flops,
                "r={}: decode flops {} must exceed r=1's {}",
                row.r,
                row.decode_flops,
                r1.decode_flops
            );
        }
        // The sweep is monotone in r on both axes.
        for w in rows.windows(2) {
            assert!(w[1].expected < w[0].expected + 3.0 * (w[0].ci95 + w[1].ci95));
            assert!(w[1].decode_flops > w[0].decode_flops);
        }
    }

    #[test]
    fn csv_renders() {
        let rows = generate(2_000, 3).unwrap();
        let csv = to_csv(&rows);
        assert_eq!(csv.lines().count(), 1 + R_SWEEP.len());
        assert!(csv.starts_with("r,"));
    }
}
