//! Fig. 7: expected total execution time `E[T_exec] = T_comp + α·T_dec`
//! of the four schemes as `α` sweeps.
//!
//! Paper parameters: `(n1,k1) = (800,400)`, `(n2,k2) = (40,20)`,
//! `(µ1,µ2) = (10,1)`, `β = 2`. `T_comp` of the hierarchical code is
//! simulated (`E[T]`, eq. 1); the baselines use their Table I closed
//! forms. Expected qualitative shape (§IV): polynomial wins at low `α`,
//! hierarchical in the moderate band (strictly beating product
//! everywhere), replication at high `α`.

use crate::coding::cost::{self, Scheme};
use crate::sim::{montecarlo, SimParams};
use crate::Result;

/// One `α` point.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    /// Decode-cost weight `α`.
    pub alpha: f64,
    /// `E[T_exec]` per scheme, in [`Scheme::ALL`] order.
    pub exec: [f64; 4],
    /// Name of the best (minimum) scheme at this `α`.
    pub winner: &'static str,
}

/// Fixed inputs of the figure.
#[derive(Clone, Debug)]
pub struct Fig7Params {
    /// Workers per group.
    pub n1: usize,
    /// Inner dimension.
    pub k1: usize,
    /// Groups.
    pub n2: usize,
    /// Outer dimension.
    pub k2: usize,
    /// Worker rate.
    pub mu1: f64,
    /// Link rate.
    pub mu2: f64,
    /// Decode exponent β.
    pub beta: f64,
}

impl Default for Fig7Params {
    fn default() -> Self {
        // The paper's Fig. 7 setting.
        Self {
            n1: 800,
            k1: 400,
            n2: 40,
            k2: 20,
            mu1: 10.0,
            mu2: 1.0,
            beta: 2.0,
        }
    }
}

/// Per-scheme `(T_comp, T_dec)` at the figure's parameters.
pub fn components(p: &Fig7Params, trials: usize, seed: u64) -> Result<[(f64, f64); 4]> {
    let n = p.n1 * p.n2;
    let k = p.k1 * p.k2;
    let sim = SimParams {
        n1: p.n1,
        k1: p.k1,
        n2: p.n2,
        k2: p.k2,
        mu1: p.mu1,
        mu2: p.mu2,
    };
    let hier_comp = montecarlo::expected_latency(&sim, trials, seed)?.mean;
    let mut out = [(0.0, 0.0); 4];
    for (i, s) in Scheme::ALL.iter().enumerate() {
        let t_comp = match s {
            Scheme::Hierarchical => hier_comp,
            other => cost::computing_time(*other, n, k, p.mu2).ok_or_else(|| {
                crate::Error::InvalidParams(format!(
                    "no closed-form T_comp for {}",
                    other.name()
                ))
            })?,
        };
        let t_dec = cost::decoding_cost(*s, p.k1 as f64, p.k2 as f64, p.beta);
        out[i] = (t_comp, t_dec);
    }
    Ok(out)
}

/// Generate rows over a log-spaced `α` grid.
pub fn generate(
    p: &Fig7Params,
    alphas: &[f64],
    trials: usize,
    seed: u64,
) -> Result<Vec<Fig7Row>> {
    let comps = components(p, trials, seed)?;
    Ok(alphas
        .iter()
        .map(|&alpha| {
            let mut exec = [0.0; 4];
            for i in 0..4 {
                exec[i] = cost::execution_time(comps[i].0, alpha, comps[i].1);
            }
            let winner_idx = (0..4)
                .min_by(|&a, &b| exec[a].partial_cmp(&exec[b]).unwrap())
                .unwrap();
            Fig7Row {
                alpha,
                exec,
                winner: Scheme::ALL[winner_idx].name(),
            }
        })
        .collect())
}

/// Default log-spaced `α` grid `10^-9 .. 10^-3`.
pub fn default_alphas() -> Vec<f64> {
    (0..25).map(|i| 10f64.powf(-9.0 + i as f64 * 0.25)).collect()
}

/// Render rows as CSV.
pub fn to_csv(rows: &[Fig7Row]) -> String {
    let mut out = String::from("alpha,replication,hierarchical,product,polynomial,winner\n");
    for r in rows {
        out.push_str(&format!(
            "{:.3e},{:.6},{:.6},{:.6},{:.6},{}\n",
            r.alpha, r.exec[0], r.exec[1], r.exec[2], r.exec[3], r.winner
        ));
    }
    out
}

/// Print the figure.
pub fn run(trials: usize, seed: u64) -> Result<Vec<Fig7Row>> {
    let p = Fig7Params::default();
    println!(
        "# Fig 7 — (n1,k1)=({},{}), (n2,k2)=({},{}), (mu1,mu2)=({},{}), beta={}",
        p.n1, p.k1, p.n2, p.k2, p.mu1, p.mu2, p.beta
    );
    let rows = generate(&p, &default_alphas(), trials, seed)?;
    print!("{}", to_csv(&rows));
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_rows() -> Vec<Fig7Row> {
        generate(&Fig7Params::default(), &default_alphas(), 3_000, 7).unwrap()
    }

    #[test]
    fn hierarchical_strictly_beats_product_everywhere() {
        // §IV: "the hierarchical code strictly outperforms the product
        // code for all values of α" — T_comp(hier) < T_comp(product) at
        // these rates and T_dec(hier) < T_dec(product).
        for r in small_rows() {
            assert!(
                r.exec[1] < r.exec[2],
                "α={}: hier {} !< product {}",
                r.alpha,
                r.exec[1],
                r.exec[2]
            );
        }
    }

    #[test]
    fn winner_transitions_poly_hier_replication() {
        // Low α → polynomial; moderate → hierarchical; high → replication.
        let rows = small_rows();
        assert_eq!(rows.first().unwrap().winner, "polynomial");
        assert_eq!(rows.last().unwrap().winner, "replication");
        assert!(
            rows.iter().any(|r| r.winner == "hierarchical"),
            "hierarchical must win a moderate-α band"
        );
        // Winners appear in the paper's order (no interleaving back).
        let order: Vec<&str> = {
            let mut o = Vec::new();
            for r in &rows {
                if o.last() != Some(&r.winner) {
                    o.push(r.winner);
                }
            }
            o
        };
        assert_eq!(order, vec!["polynomial", "hierarchical", "replication"]);
    }

    #[test]
    fn exec_monotone_in_alpha() {
        let rows = small_rows();
        for w in rows.windows(2) {
            for s in 0..4 {
                assert!(w[1].exec[s] >= w[0].exec[s]);
            }
        }
    }
}
