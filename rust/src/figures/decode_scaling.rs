//! §IV decode-cost scaling: the hierarchical/product gain as a function
//! of `p` where `k1 = k2^p` — both the analytic model (the paper's
//! claim that the gain grows monotonically in `p`) and the measured
//! flops of the real decoders at feasible sizes.

use crate::coding::cost::{self, Scheme};
use crate::Result;

/// One `(k2, p)` point.
#[derive(Clone, Debug)]
pub struct ScalingRow {
    /// Outer dimension.
    pub k2: usize,
    /// Exponent `p` in `k1 = k2^p`.
    pub p: f64,
    /// Resulting `k1`.
    pub k1: usize,
    /// Model cost, hierarchical.
    pub model_hier: f64,
    /// Model cost, product.
    pub model_product: f64,
    /// Model gain (product / hierarchical).
    pub model_gain: f64,
    /// Measured decode flops (hier, product, polynomial) at this size,
    /// when the decode is feasible in-memory (small sizes only).
    pub measured: Option<(u64, u64, u64)>,
}

/// Generate the scaling sweep. `measure_limit` caps `n1·n2` for the
/// real-decoder measurements.
pub fn generate(beta: f64, measure_limit: usize, seed: u64) -> Result<Vec<ScalingRow>> {
    let mut rows = Vec::new();
    for k2 in [2usize, 3, 4] {
        for &p in &[1.0, 1.5, 2.0] {
            let k1 = (k2 as f64).powf(p).round() as usize;
            if k1 < 1 {
                continue;
            }
            let model_hier = cost::decoding_cost(Scheme::Hierarchical, k1 as f64, k2 as f64, beta);
            let model_product = cost::decoding_cost(Scheme::Product, k1 as f64, k2 as f64, beta);
            let (n1, n2) = (2 * k1, 2 * k2);
            let rows_m = k1 * k2 * 2;
            let measured = if n1 * n2 <= measure_limit {
                // Drop k1 workers to force parity decodes in every scheme.
                Some(cost::measured::decode_flops(n1, k1, n2, k2, rows_m, k1, seed)?)
            } else {
                None
            };
            rows.push(ScalingRow {
                k2,
                p,
                k1,
                model_hier,
                model_product,
                model_gain: model_product / model_hier,
                measured,
            });
        }
    }
    Ok(rows)
}

/// Render as CSV.
pub fn to_csv(rows: &[ScalingRow]) -> String {
    let mut out = String::from(
        "k2,p,k1,model_hier,model_product,model_gain,meas_hier,meas_product,meas_poly\n",
    );
    for r in rows {
        let (mh, mp, my) = r
            .measured
            .map(|(a, b, c)| (a.to_string(), b.to_string(), c.to_string()))
            .unwrap_or_else(|| ("".into(), "".into(), "".into()));
        out.push_str(&format!(
            "{},{},{},{:.1},{:.1},{:.3},{mh},{mp},{my}\n",
            r.k2, r.p, r.k1, r.model_hier, r.model_product, r.model_gain
        ));
    }
    out
}

/// Print the sweep.
pub fn run(seed: u64) -> Result<Vec<ScalingRow>> {
    println!("# §IV decode-cost scaling: k1 = k2^p, beta = 2");
    let rows = generate(2.0, 200, seed)?;
    print!("{}", to_csv(&rows));
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gain_monotone_in_p_per_k2() {
        let rows = generate(2.0, 0, 1).unwrap(); // model only
        for k2 in [2usize, 3, 4] {
            let gains: Vec<f64> = rows
                .iter()
                .filter(|r| r.k2 == k2)
                .map(|r| r.model_gain)
                .collect();
            for w in gains.windows(2) {
                assert!(
                    w[1] >= w[0],
                    "k2={k2}: gain not monotone in p: {gains:?}"
                );
            }
        }
    }

    #[test]
    fn measured_flops_available_at_small_sizes() {
        let rows = generate(2.0, 200, 2).unwrap();
        let with_measured = rows.iter().filter(|r| r.measured.is_some()).count();
        assert!(with_measured >= 4, "want several measured points");
        for r in rows.iter().filter(|r| r.measured.is_some()) {
            let (h, p, y) = r.measured.unwrap();
            assert!(h > 0 && p > 0 && y > 0);
            // The polynomial decode (monolithic k×k solve) must be the
            // most expensive in flops at every measured point.
            assert!(h <= y && p <= y, "k2={} p={}: h={h} p={p} y={y}", r.k2, r.p);
        }
    }
}
