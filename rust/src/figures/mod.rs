//! Regeneration of every table and figure in the paper's evaluation.
//!
//! | ID | Paper artifact | Function |
//! |----|----------------|----------|
//! | E1 | Fig. 6a — `E[T]` + bounds vs `k2`, `k1 = 5`   | [`fig6::generate`] |
//! | E2 | Fig. 6b — same, `k1 = 300`                    | [`fig6::generate`] |
//! | E3 | Fig. 7 — `E[T_exec]` vs `α`, four schemes     | [`fig7::generate`] |
//! | E4 | Table I — `T_comp` / `T_dec` per scheme       | [`table1::generate`] |
//! | E6 | §IV decode-cost scaling in `p` (`k1 = k2^p`)  | [`decode_scaling::generate`] |
//! | E7 | Allocation — uniform vs optimized `k1_g` E[T] | [`allocation::generate`] |
//! | E8 | Partial work — `E[T]` / decode cost vs `r`    | [`partial::generate`] |
//!
//! Each generator returns structured rows and renders CSV (stdout) so
//! series can be re-plotted; EXPERIMENTS.md quotes these outputs.
//! E7 goes beyond the paper: it sweeps straggler skew and reports what
//! the `sim::allocate` optimizer buys over the uniform assignment.
//! E8 reproduces the Ferdinand–Draper multi-round tradeoff
//! (arXiv:1806.10250) on top of the hierarchical outer code: expected
//! latency falls with `subtasks_per_worker` while decode cost rises.

pub mod allocation;
pub mod decode_scaling;
pub mod fig6;
pub mod fig7;
pub mod partial;
pub mod table1;
