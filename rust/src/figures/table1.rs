//! Table I: computing time and decoding cost of the four schemes,
//! evaluated at the paper's Fig. 7 parameters, with the analytic
//! entries cross-checked against simulation and *measured* decode
//! flops from the real decoders (at a scaled-down size).

use crate::coding::cost::{self, Scheme};
use crate::sim::{markov, montecarlo, SimParams};
use crate::Result;

/// One scheme's Table I row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Scheme name.
    pub scheme: &'static str,
    /// Computing time `T_comp` (analytic, or simulated for hierarchical).
    pub t_comp: f64,
    /// Decoding cost `T_dec` (unit-constant model).
    pub t_dec: f64,
    /// Decode flops measured from the real decoder at the scaled-down
    /// validation size (None for replication — free by construction).
    pub measured_flops: Option<u64>,
}

/// Generate Table I at parameters `(n1,k1)×(n2,k2)`, `(µ1,µ2)`, β.
#[allow(clippy::too_many_arguments)]
pub fn generate(
    n1: usize,
    k1: usize,
    n2: usize,
    k2: usize,
    mu1: f64,
    mu2: f64,
    beta: f64,
    trials: usize,
    seed: u64,
) -> Result<Vec<Table1Row>> {
    let n = n1 * n2;
    let k = k1 * k2;
    let sim = SimParams {
        n1,
        k1,
        n2,
        k2,
        mu1,
        mu2,
    };
    let hier_t = montecarlo::expected_latency(&sim, trials, seed)?.mean;
    // Measured decode flops at a scaled-down but parity-forcing size.
    let (mh, mp, my) = cost::measured::decode_flops(6, 3, 4, 2, 24, 3, seed)?;
    let rows = Scheme::ALL
        .iter()
        .map(|s| {
            let t_comp = match s {
                Scheme::Hierarchical => hier_t,
                other => cost::computing_time(*other, n, k, mu2).unwrap_or(f64::NAN),
            };
            Table1Row {
                scheme: s.name(),
                t_comp,
                t_dec: cost::decoding_cost(*s, k1 as f64, k2 as f64, beta),
                measured_flops: match s {
                    Scheme::Replication => None,
                    Scheme::Hierarchical => Some(mh),
                    Scheme::Product => Some(mp),
                    Scheme::Polynomial => Some(my),
                },
            }
        })
        .collect();
    Ok(rows)
}

/// Render as a Markdown table (the paper's presentation).
pub fn to_markdown(rows: &[Table1Row]) -> String {
    let mut out = String::from(
        "| Scheme | T_comp | T_dec (model) | measured decode flops (scaled) |\n|---|---|---|---|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.4} | {:.3e} | {} |\n",
            r.scheme,
            r.t_comp,
            r.t_dec,
            r.measured_flops
                .map(|f| f.to_string())
                .unwrap_or_else(|| "0 (free)".into()),
        ));
    }
    out
}

/// Print the table at the paper's parameters, plus the lower bound for
/// reference.
pub fn run(trials: usize, seed: u64) -> Result<Vec<Table1Row>> {
    let (n1, k1, n2, k2) = (800, 400, 40, 20);
    println!("# Table I — (n1,k1)=({n1},{k1}), (n2,k2)=({n2},{k2}), (mu1,mu2)=(10,1), beta=2");
    let rows = generate(n1, k1, n2, k2, 10.0, 1.0, 2.0, trials, seed)?;
    print!("{}", to_markdown(&rows));
    let l = markov::lower_bound(&SimParams {
        n1,
        k1,
        n2,
        k2,
        mu1: 10.0,
        mu2: 1.0,
    })?;
    println!("\n(hierarchical lower bound L = {l:.4})");
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_papers_qualitative_ordering() {
        let rows = generate(800, 400, 40, 20, 10.0, 1.0, 2.0, 2_000, 3).unwrap();
        assert_eq!(rows.len(), 4);
        let by_name = |n: &str| rows.iter().find(|r| r.scheme == n).unwrap().clone();
        let rep = by_name("replication");
        let hier = by_name("hierarchical");
        let prod = by_name("product");
        let poly = by_name("polynomial");
        // Decode-cost ordering: rep(0) < hier < prod < poly.
        assert_eq!(rep.t_dec, 0.0);
        assert!(hier.t_dec < prod.t_dec);
        assert!(prod.t_dec < poly.t_dec);
        // Computing-time: replication is worst (waits for whole blocks
        // at low parallel redundancy), coded schemes are comparable.
        assert!(rep.t_comp > poly.t_comp);
        assert!(hier.t_comp > 0.0 && hier.t_comp.is_finite());
        // Measured flops respect the model's ordering (hier < poly).
        assert!(hier.measured_flops.unwrap() < poly.measured_flops.unwrap());
    }

    #[test]
    fn markdown_renders() {
        let rows = generate(8, 4, 4, 2, 10.0, 1.0, 2.0, 500, 4).unwrap();
        let md = to_markdown(&rows);
        assert_eq!(md.lines().count(), 6);
        assert!(md.contains("replication"));
    }
}
