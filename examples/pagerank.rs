//! Power iteration (PageRank-style) on a synthetic link matrix, with
//! every `M·v` product served by the coded cluster — including a
//! mid-run **rack failure**: after half the iterations, one whole
//! group's uplink "dies" and the computation proceeds without it,
//! demonstrating the `n2 − k2` group redundancy of §II-A.
//!
//! ```bash
//! cargo run --release --example pagerank
//! ```

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::fault::FaultConfig;
use hiercode::coordinator::Cluster;
use hiercode::linalg::{ops, Matrix};
use hiercode::util::rng::Rng;

/// Build a column-stochastic link matrix with damping.
fn link_matrix(n: usize, damping: f64, rng: &mut Rng) -> Matrix {
    // Random sparse-ish adjacency: ~8 outlinks per node.
    let mut adj = Matrix::zeros(n, n);
    for j in 0..n {
        let outdeg = 4 + rng.next_below(8);
        for _ in 0..outdeg {
            let i = rng.next_below(n);
            adj[(i, j)] = 1.0;
        }
    }
    // Column-normalize; dangling columns get uniform.
    for j in 0..n {
        let col_sum: f64 = (0..n).map(|i| adj[(i, j)]).sum();
        if col_sum == 0.0 {
            for i in 0..n {
                adj[(i, j)] = 1.0 / n as f64;
            }
        } else {
            for i in 0..n {
                adj[(i, j)] /= col_sum;
            }
        }
    }
    // M = damping·adj + (1−damping)/n · 1
    Matrix::from_fn(n, n, |i, j| {
        damping * adj[(i, j)] + (1.0 - damping) / n as f64
    })
}

fn l1_normalize(v: &mut [f64]) {
    let s: f64 = v.iter().map(|x| x.abs()).sum();
    if s > 0.0 {
        for x in v.iter_mut() {
            *x /= s;
        }
    }
}

fn main() -> hiercode::Result<()> {
    // n = 128 pages → square M: shard 32×128 under (2,1)x(4,2)... rows
    // must divide k1·k2: use (4,2)x(2,2): k1·k2 = 4 → shards 32×128.
    let n = 128usize;
    let mut rng = Rng::new(99);
    let m = link_matrix(n, 0.85, &mut rng);
    // Reference ranks by direct power iteration.
    let mut ref_v = vec![1.0 / n as f64; n];
    for _ in 0..40 {
        ref_v = ops::matvec(&m, &ref_v);
        l1_normalize(&mut ref_v);
    }

    let mut config = ClusterConfig::demo(4, 2, 4, 2);
    config.straggler.enabled = true;
    config.straggler.scale = 0.001;

    // Phase 1: healthy cluster, 20 iterations.
    let cluster = Cluster::launch(&config, &m)?;
    let mut v = vec![1.0 / n as f64; n];
    for _ in 0..20 {
        v = cluster.submit(v)?.wait()?;
        l1_normalize(&mut v);
    }
    let m1 = cluster.metrics();
    cluster.shutdown();

    // Phase 2: rack 0's uplink severed AND two of its workers dead —
    // the remaining n2−1 = 3 ≥ k2 = 2 groups carry the job.
    let faults = FaultConfig::none()
        .with_dead_links(&[0])
        .with_dead_workers(&[(1, 0), (1, 1)]); // group 1 down to k1 = 2
    assert!(faults.survivable(4, 2, 4, 2));
    let degraded = Cluster::launch_with_faults(&config, &m, faults)?;
    for _ in 0..20 {
        v = degraded.submit(v)?.wait()?;
        l1_normalize(&mut v);
    }
    let m2 = degraded.metrics();
    degraded.shutdown();

    // Validate convergence to the reference ranks.
    let max_err = v
        .iter()
        .zip(ref_v.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("pagerank: n={n}, 20 healthy + 20 degraded iterations");
    println!("max |rank − reference| = {max_err:.2e}");
    assert!(max_err < 1e-6, "power iteration must converge to reference");

    // Top-5 pages.
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| v[b].partial_cmp(&v[a]).unwrap());
    println!("top-5 pages: {:?}", &idx[..5]);

    println!("\nhealthy-phase metrics:\n{m1}");
    println!("\ndegraded-phase metrics (rack 0 uplink dead, 2 workers of rack 1 dead):\n{m2}");
    println!("\npagerank with rack failure OK");
    Ok(())
}
