//! End-to-end driver (EXPERIMENTS.md §E2E): distributed linear
//! regression by gradient descent, with every matrix-vector product
//! served by the hierarchical coded cluster under straggler injection.
//!
//! The workload the paper's introduction motivates: iterative ML
//! training whose per-step latency is gated by distributed `A·x`
//! products. Model: least squares `min_w ‖A·w − y‖²`. Each GD step
//! needs `u = A·w` and `g = Aᵀ·(u − y)`; both products run on coded
//! clusters (one for `A`, one for `Aᵀ`), so every step exercises
//! encode → dispatch → straggle → k1/k2 collection → two-level decode.
//!
//! ```bash
//! cargo run --release --example regression             # native
//! HIERCODE_PJRT=1 cargo run --release --example regression   # PJRT
//! ```

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::Cluster;
use hiercode::linalg::{ops, Matrix};
use hiercode::util::rng::Rng;
use std::time::Instant;

fn main() -> hiercode::Result<()> {
    let use_pjrt = std::env::var("HIERCODE_PJRT").is_ok();
    // Problem: m=1024 samples, d=128 features — shard shape 256×128 for
    // A under (4,2)x(4,2)... A is m×d = 1024×128: k1·k2 = 4 → shards
    // 256×128 (AOT: worker_matvec_r256_d128_*). Aᵀ is 128×1024: use a
    // (2,1)x(4,2) code → shards 64×1024 — native backend (no artifact);
    // PJRT mode demonstrates the A-side product on the hot path.
    let (m, d) = (1024usize, 128usize);
    let mut rng = Rng::new(2024);
    let a = Matrix::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0) / (d as f64).sqrt());
    // Ground-truth weights and noisy labels.
    let w_true: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let mut y = ops::matvec(&a, &w_true);
    for v in y.iter_mut() {
        *v += 0.01 * rng.normal();
    }

    // Cluster for A·w (the PJRT-accelerated hot path).
    let mut config = ClusterConfig::demo(4, 2, 4, 2);
    config.runtime.use_pjrt = use_pjrt;
    config.straggler.enabled = true;
    config.straggler.scale = 0.002; // Exp(10) worker ≈ 0.2ms mean sleep
    let cluster_a = Cluster::launch(&config, &a)?;

    // Cluster for Aᵀ·r (native: transpose shards have no AOT shape).
    let mut config_t = ClusterConfig::demo(2, 1, 4, 2);
    config_t.runtime.use_pjrt = false;
    config_t.straggler.enabled = true;
    config_t.straggler.scale = 0.002;
    let at = a.transpose();
    let cluster_at = Cluster::launch(&config_t, &at)?;

    println!(
        "# regression: m={m} d={d}, A-cluster (4,2)x(4,2) backend={}, Aᵀ-cluster (2,1)x(4,2) native",
        if use_pjrt { "PJRT" } else { "native" }
    );
    println!("step,loss,step_ms");

    // A's entries are U(-1,1)/√d, so the Hessian AᵀA/m has eigenvalues
    // ≈ (√m ± √d)²/(3·d·m) ∈ [~0.001, ~0.005]; lr = 300 sits safely
    // under 2/λ_max while contracting the smallest mode fast.
    let steps = 60;
    let lr = 300.0;
    let mut w = vec![0.0f64; d];
    let mut losses = Vec::new();
    let t_total = Instant::now();
    for step in 0..steps {
        let t0 = Instant::now();
        // u = A·w  (coded product #1)
        let u = cluster_a.submit(w.clone())?.wait()?;
        // r = u − y; loss = ‖r‖²/m
        let r: Vec<f64> = u.iter().zip(y.iter()).map(|(a, b)| a - b).collect();
        let loss = r.iter().map(|x| x * x).sum::<f64>() / m as f64;
        // g = Aᵀ·r / m  (coded product #2)
        let g = cluster_at.submit(r)?.wait()?;
        for (wi, gi) in w.iter_mut().zip(g.iter()) {
            *wi -= lr * gi / m as f64;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        losses.push(loss);
        if step % 5 == 0 || step == steps - 1 {
            println!("{step},{loss:.6},{ms:.2}");
        }
    }
    let wall = t_total.elapsed().as_secs_f64();

    // Validation: loss decreased by orders of magnitude and w ≈ w_true.
    let first = losses[0];
    let last = *losses.last().unwrap();
    let w_err = w
        .iter()
        .zip(w_true.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("# loss {first:.4} -> {last:.6} ({:.0}x), max|w - w*| = {w_err:.4}, wall {wall:.2}s", first / last);
    assert!(last < first / 50.0, "GD must converge (loss {first} -> {last})");
    assert!(w_err < 0.2, "weights must approach the ground truth");

    println!("\n# A-cluster metrics:\n{}", cluster_a.metrics());
    println!("\n# Aᵀ-cluster metrics:\n{}", cluster_at.metrics());
    cluster_a.shutdown();
    cluster_at.shutdown();
    println!("\nregression E2E OK");
    Ok(())
}
