//! Quickstart: encode a matrix with the `(n1,k1)×(n2,k2)` hierarchical
//! code, launch the in-process cluster, and serve one request.
//!
//! ```bash
//! cargo run --release --example quickstart            # native backend
//! HIERCODE_PJRT=1 cargo run --release --example quickstart  # PJRT
//! ```

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::Cluster;
use hiercode::linalg::{ops, Matrix};
use hiercode::util::rng::Rng;

fn main() -> hiercode::Result<()> {
    // (3,2) x (3,2): the paper's Fig. 3 toy code — 9 workers in 3
    // groups; any 2 workers per group, any 2 groups suffice.
    let mut config = ClusterConfig::demo(3, 2, 3, 2);
    config.runtime.use_pjrt = std::env::var("HIERCODE_PJRT").is_ok();

    // A small data matrix A (rows divisible by k1·k2 = 4).
    let (m, d) = (64, 32);
    let mut rng = Rng::new(7);
    let a = Matrix::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0));

    // PJRT note: with use_pjrt=true the shard shape m/(k1·k2) × d =
    // 16×32 must have an AOT artifact — worker_matvec_r16_d32_b1 ships
    // in the default artifact set.
    let cluster = Cluster::launch(&config, &a)?;
    println!(
        "cluster: 9 workers in 3 groups, backend = {}",
        if config.runtime.use_pjrt { "PJRT" } else { "native" }
    );

    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let y = cluster.submit(x.clone())?.wait()?;

    // Verify against a direct product.
    let expect = ops::matvec(&a, &x);
    let max_err = y
        .iter()
        .zip(expect.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("A·x computed by the cluster; max |err| vs direct = {max_err:.2e}");
    assert!(max_err < 1e-3, "coded result must match direct product");

    println!("\nmetrics:\n{}", cluster.metrics());
    cluster.shutdown();
    println!("\nquickstart OK");
    Ok(())
}
