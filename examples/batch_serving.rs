//! Batched serving under load: many concurrent clients submit `A·x`
//! requests; the batcher folds them into MXU-shaped jobs; the report
//! compares per-request latency and throughput across batch policies —
//! the knob the coordinator adds on top of the paper's scheme.
//!
//! ```bash
//! cargo run --release --example batch_serving
//! ```

use hiercode::config::schema::ClusterConfig;
use hiercode::coordinator::Cluster;
use hiercode::linalg::Matrix;
use hiercode::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn run_load(cluster: Arc<Cluster>, clients: usize, per_client: usize, d: usize) -> (f64, f64) {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let cluster = Arc::clone(&cluster);
            std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64 + 1);
                for _ in 0..per_client {
                    let x: Vec<f64> = (0..d).map(|_| rng.uniform(-1.0, 1.0)).collect();
                    cluster
                        .submit(x)
                        .expect("submit")
                        .wait()
                        .expect("request should succeed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = (clients * per_client) as f64;
    (total / wall, wall)
}

fn main() -> hiercode::Result<()> {
    let (m, d) = (1024usize, 128usize);
    let mut rng = Rng::new(5);
    let a = Matrix::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0));
    let (clients, per_client) = (8usize, 12usize);

    println!("# batch serving: {clients} clients x {per_client} requests, m={m} d={d}");
    println!("max_batch,throughput_rps,wall_s,jobs,mean_ms,p99_ms");
    for max_batch in [1usize, 4, 8] {
        let mut config = ClusterConfig::demo(4, 2, 4, 2);
        config.batching.max_batch = max_batch;
        config.batching.max_wait_ms = 2.0;
        config.straggler.enabled = true;
        config.straggler.scale = 0.002;
        let cluster = Arc::new(Cluster::launch(&config, &a)?);
        let (rps, wall) = run_load(Arc::clone(&cluster), clients, per_client, d);
        let snap = cluster.metrics();
        println!(
            "{max_batch},{rps:.1},{wall:.3},{},{:.2},{:.2}",
            snap.jobs,
            snap.latency_mean * 1e3,
            snap.latency_p99 * 1e3
        );
        Arc::try_unwrap(cluster)
            .map(|c| c.shutdown())
            .unwrap_or(());
    }
    println!("\n# larger max_batch → fewer jobs (amortized straggler waits + decodes)");
    println!("batch_serving OK");
    Ok(())
}
